/* Row-broadcast scale over an m x n matrix — the nested-loop shape:
 * the outer loop walks rows (scalar; its pointers advance through the
 * inner loops, so it must stay narrow), the inner strip multiplies a
 * row by its broadcast scale, and the inner scalar tail cleans up.
 * Re-tiling hoists into the inner strip only.
 *   y[i*n + j] = x[i*n + j] * s[i]                                    */
#include <arm_neon.h>

void f32_rowscale_ukernel(size_t m, size_t n, const float* x,
                          const float* s, float* y) {
  for (; m != 0; m -= 1) {
    const float sv = *s; s += 1;
    float32x4_t vs = vdupq_n_f32(sv);
    size_t nn = n;
    for (; nn >= 4; nn -= 4) {
      float32x4_t vx = vld1q_f32(x); x += 4;
      vst1q_f32(y, vmulq_f32(vx, vs)); y += 4;
    }
    for (; nn != 0; nn -= 1) {
      *y = *x * sv;
      x += 1; y += 1;
    }
  }
}
