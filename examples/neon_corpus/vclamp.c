/* XNNPACK-style f32 clamp (vrelu with both bounds): scalar bounds are
 * broadcast once, the strip loop is pure vmax/vmin. */
#include <arm_neon.h>

void xnn_f32_vclamp_ukernel(size_t n, const float* x, float* y,
                            float output_min, float output_max) {
  const float32x4_t vmin = vdupq_n_f32(output_min);
  const float32x4_t vmax = vdupq_n_f32(output_max);
  for (; n >= 4; n -= 4) {
    float32x4_t vacc = vld1q_f32(x); x += 4;
    vacc = vmaxq_f32(vacc, vmin);
    vacc = vminq_f32(vacc, vmax);
    vst1q_f32(y, vacc); y += 4;
  }
  for (; n != 0; n -= 1) {
    float vx = *x; x += 1;
    vx = vx < output_min ? output_min : vx;
    vx = vx > output_max ? output_max : vx;
    *y = vx; y += 1;
  }
}
