"""Differential harness for the NEON corpus.

Each corpus kernel gets (a) an argument builder fixing buffer shapes and
(b) a NumPy reference implementing the *same algorithm* in float32 (not
a looser mathematical ideal), so ported execution must match tightly —
the SIMDe unit-test methodology.  ``run_differential()`` compiles every
``.c`` file, executes it through ``registry.dispatch`` under the given
target/policy, and asserts against the reference.

Run directly:  PYTHONPATH=src python examples/neon_corpus/harness.py
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

CORPUS_DIR = os.path.dirname(os.path.abspath(__file__))

F = np.float32


@dataclasses.dataclass(frozen=True)
class Case:
    file: str
    kernel: str
    make_args: Callable[[np.random.Generator], tuple]
    reference: Callable[..., tuple]
    rtol: float = 1e-6
    atol: float = 1e-6


def _rand(rng, n, lo=-2.0, hi=2.0):
    return rng.uniform(lo, hi, n).astype(F)


# -- reference algorithms (float32 mirrors of the kernels) -------------------

def _tanh_rational(t: np.ndarray) -> np.ndarray:
    t = np.clip(t, F(-4.0), F(4.0))
    t2 = t * t
    p = t2 + F(378.0)
    p = p * t2 + F(17325.0)
    p = p * t2 + F(135135.0)
    p = p * t
    q = t2 * F(28.0) + F(3150.0)
    q = q * t2 + F(62370.0)
    q = q * t2 + F(135135.0)
    r = (F(1.0) / q).astype(F)
    r = r * (F(2.0) - q * r)
    r = r * (F(2.0) - q * r)
    return (p * r).astype(F)


def _ref_vadd(n, a, b, y):
    out = y.copy()
    out[:n] = a[:n] + b[:n]
    return out


def _ref_vmul(n, a, b, y):
    out = y.copy()
    out[:n] = a[:n] * b[:n]
    return out


def _ref_vmulcaddc(n, x, scale, bias, y):
    out = y.copy()
    m = (n // 4) * 4
    k = m // 4
    out[:m] = x[:m] * np.tile(scale, k) + np.tile(bias, k)
    return out


def _ref_vclamp(n, x, y, lo, hi):
    out = y.copy()
    out[:n] = np.clip(x[:n], F(lo), F(hi))
    return out


def _ref_vtanh(n, x, y):
    out = y.copy()
    m = (n // 4) * 4
    out[:m] = _tanh_rational(x[:m])
    return out


def _ref_vsigmoid(n, x, y):
    out = y.copy()
    m = (n // 4) * 4
    th = _tanh_rational((x[:m] * F(0.5)).astype(F))
    out[:m] = F(0.5) + th * F(0.5)
    return out


def _ref_vdot(n, a, b, sum_buf):
    m = (n // 4) * 4
    acc = np.zeros(4, F)
    for i in range(0, m, 4):
        acc = acc + a[i:i + 4] * b[i:i + 4]
    s = F(acc.sum())
    for i in range(m, n):
        s = F(s + a[i] * b[i])
    out = sum_buf.copy()
    out[0] = s
    return out


def _ref_vrsqrt(n, x, y):
    out = y.copy()
    m = (n // 4) * 4
    v = x[:m]
    r = (F(1.0) / np.sqrt(v)).astype(F)
    r = r * ((F(3.0) - (v * r) * r) * F(0.5))
    r = r * ((F(3.0) - (v * r) * r) * F(0.5))
    out[:m] = r
    return out


def _ref_vfold(n, x, y):
    out = y.copy()
    m = (n // 4) * 4
    q = x[:m].reshape(-1, 4)
    out[:m // 2] = (q[:, 2:] + q[:, :2]).reshape(-1)
    return out


def _ref_vselect(n, x, y):
    out = y.copy()
    m = (n // 4) * 4
    out[:m] = np.where(x[:m] > 0, x[:m], F(0.0))
    return out


def _ref_vrbit(n, x, y):
    out = y.copy()
    m = (n // 16) * 16
    v = x[:m]
    v = ((v >> 1) & 0x55) | ((v & 0x55) << 1)
    v = ((v >> 2) & 0x33) | ((v & 0x33) << 2)
    v = ((v >> 4) & 0x0F) | ((v & 0x0F) << 4)
    out[:m] = v
    return out


def _ref_vqaddsub(n, a, b, ya, ys):
    outa, outs = ya.copy(), ys.copy()
    s = np.clip(a[:n].astype(np.int32) + b[:n].astype(np.int32), -128, 127)
    d = np.clip(a[:n].astype(np.int32) - b[:n].astype(np.int32), -128, 127)
    outa[:n] = (s + 128).astype(np.uint8)
    outs[:n] = (d + 128).astype(np.uint8)
    return outa, outs


def _ref_reduce_max(n, x, out_buf):
    out = out_buf.copy()
    # the kernel seeds its accumulator with x[0] before the strip loop,
    # so the n == 0 result is x[0] (and x[0] participates for any n)
    out[0] = np.max(x[:max(n, 1)])
    return out


def _ref_vcvt(n, x, y):
    out = y.copy()
    m = (n // 4) * 4
    out[:m] = x[:m].astype(np.int32)    # C truncation semantics
    return out


def _ref_vaddl_requant(n, a, b, bias, y):
    out = y.copy()
    s = a[:n].astype(np.int32) + b[:n].astype(np.int32) + bias
    out[:n] = np.clip(s, 0, 255).astype(np.uint8)
    return out


def _ref_vmull_requant(n, a, b, y):
    out = y.copy()
    p = (a[:n].astype(np.int32) * b[:n].astype(np.int32)) >> 5
    out[:n] = np.clip(p, -128, 127).astype(np.int8)
    return out


def _ref_shl1_widen_narrow(n, x, y):
    out = y.copy()
    t = (x[:n].astype(np.int16) << 1) & 0xFF
    out[:n] = t.astype(np.uint8).view(np.int8)
    return out


def _ref_cmul(n, a, b, y):
    """n complex pairs; the strip computes in float32 two-step (vmul,
    then vmls/vmla), the scalar tail in double rounded once at store —
    the reference mirrors both exactly."""
    out = y.copy()
    m = (n // 4) * 4
    ar, ai = a[0:2 * m:2], a[1:2 * m:2]
    br, bi = b[0:2 * m:2], b[1:2 * m:2]
    out[0:2 * m:2] = ar * br - ai * bi
    out[1:2 * m:2] = ar * bi + ai * br
    for i in range(m, n):
        re = float(a[2 * i]) * float(b[2 * i]) - \
            float(a[2 * i + 1]) * float(b[2 * i + 1])
        im = float(a[2 * i]) * float(b[2 * i + 1]) + \
            float(a[2 * i + 1]) * float(b[2 * i])
        out[2 * i] = np.float32(re)
        out[2 * i + 1] = np.float32(im)
    return out


def _ref_vld3_rgbx(n, rgb, r, g, b):
    """Packed RGB split into planes: member i of each pixel triple."""
    ro, go, bo = r.copy(), g.copy(), b.copy()
    ro[:n] = rgb[0:3 * n:3]
    go[:n] = rgb[1:3 * n:3]
    bo[:n] = rgb[2:3 * n:3]
    return ro, go, bo


def _ref_vmlal_dot(n, a, b, sum_buf):
    # integer accumulation is associative — exact in any order as long
    # as the int16 accumulator cannot overflow (the args builder keeps
    # |a*b| <= 4, so |sum| <= 4n stays well inside int16 for corpus n)
    out = sum_buf.copy()
    out[0] = np.int16(np.dot(a[:n].astype(np.int32),
                             b[:n].astype(np.int32)))
    return out


def _ref_rowscale(m, n, x, s, y):
    out = y.copy()
    if m and n:
        out[:m * n] = (x[:m * n].reshape(m, n) * s[:m, None]).reshape(-1)
    return out


def _ref_butterfly(n, x, y):
    # no scalar tail: the kernel floors to whole 8-float strips
    out = y.copy()
    w = n - n % 8
    e, o = x[0:w:2], x[1:w:2]
    out[0:w:2] = e + o
    out[1:w:2] = e - o
    return out


def _ref_qs8_gemm(m, k, a, b, c):
    out = c.copy()
    if m:
        a2 = a[:m * k].astype(np.int32).reshape(m, k)
        b2 = b[:k * 8].astype(np.int32).reshape(k, 8)
        out[:m * 8] = (a2 @ b2).astype(np.int16).reshape(-1)
    return out


# -- the corpus ---------------------------------------------------------------

def cases(n: int = 64, tail_n: int = 67, seed: int = 0) -> Sequence[Case]:
    """``n`` drives strip-only kernels (a multiple of 16 covers every
    strip width exactly; any value is legal — references mirror the
    kernels' floor-to-strip semantics, which is what the conformance
    suite sweeps); ``tail_n`` drives the kernels with scalar tails
    (deliberately not a multiple of 4 by default)."""

    def args_abn(rng):     # (n, a, b, y) with tail
        return (tail_n, _rand(rng, tail_n), _rand(rng, tail_n),
                np.zeros(tail_n, F))

    def gemm_args(rng):    # m x 8 tile over k = n (small operands: the
        # int16 accumulator must stay exact — |sum| <= 4 * k)
        m, k = 3, n
        return (m, k,
                rng.integers(-2, 3, max(1, m * k)).astype(np.int8),
                rng.integers(-2, 3, max(1, k * 8)).astype(np.int8),
                np.zeros(m * 8, np.int16))

    def rowscale_args(rng):   # 3 rows of tail_n (inner strip + inner
        # scalar tail per row; the outer row loop stays scalar)
        m = 3
        return (m, tail_n, _rand(rng, max(1, m * tail_n)),
                _rand(rng, m, 0.5, 1.5),
                np.zeros(max(1, m * tail_n), F))

    return [
        Case("vadd.c", "xnn_f32_vadd_ukernel", args_abn, _ref_vadd),
        Case("vadd_x2.c", "xnn_f32_vadd_x2_ukernel", args_abn,
             _ref_vadd),
        Case("rowscale.c", "f32_rowscale_ukernel", rowscale_args,
             _ref_rowscale),
        Case("butterfly.c", "f32_butterfly_ukernel",
             lambda rng: (tail_n, _rand(rng, max(1, tail_n)),
                          np.zeros(max(1, tail_n), F)),
             _ref_butterfly),
        Case("vmul.c", "xnn_f32_vmul_ukernel", args_abn, _ref_vmul),
        Case("vmulcaddc.c", "xnn_f32_vmulcaddc_ukernel_c4",
             lambda rng: (n, _rand(rng, n), _rand(rng, 4, 0.5, 1.5),
                          _rand(rng, 4), np.zeros(n, F)),
             _ref_vmulcaddc),
        Case("vclamp.c", "xnn_f32_vclamp_ukernel",
             lambda rng: (tail_n, _rand(rng, tail_n, -3, 3),
                          np.zeros(tail_n, F), -1.0, 1.5),
             _ref_vclamp),
        Case("vtanh.c", "xnn_f32_vtanh_ukernel",
             lambda rng: (n, _rand(rng, n, -6, 6), np.zeros(n, F)),
             _ref_vtanh, rtol=2e-5, atol=1e-6),
        Case("vsigmoid.c", "xnn_f32_vsigmoid_ukernel",
             lambda rng: (n, _rand(rng, n, -8, 8), np.zeros(n, F)),
             _ref_vsigmoid, rtol=2e-5, atol=1e-6),
        Case("vdot.c", "xnn_f32_vdot_ukernel",
             lambda rng: (tail_n, _rand(rng, tail_n), _rand(rng, tail_n),
                          np.zeros(1, F)),
             _ref_vdot, rtol=1e-5),
        Case("vrsqrt.c", "xnn_f32_vrsqrt_ukernel",
             lambda rng: (n, _rand(rng, n, 0.01, 9.0), np.zeros(n, F)),
             _ref_vrsqrt, rtol=1e-5),
        Case("vfold.c", "fold_halves_f32",
             lambda rng: (n, _rand(rng, n), np.zeros(n // 2, F)),
             _ref_vfold),
        Case("vselect.c", "relu_bsl_f32",
             lambda rng: (n, _rand(rng, n), np.zeros(n, F)),
             _ref_vselect),
        Case("vrbit.c", "bitreverse_u8",
             lambda rng: (n, rng.integers(0, 256, n).astype(np.uint8),
                          np.zeros(n, np.uint8)),
             _ref_vrbit),
        Case("vqaddsub.c", "qs8_vaddsub_biased_ukernel",
             lambda rng: (tail_n,
                          rng.integers(-128, 128, tail_n).astype(np.int8),
                          rng.integers(-128, 128, tail_n).astype(np.int8),
                          np.zeros(tail_n, np.uint8),
                          np.zeros(tail_n, np.uint8)),
             _ref_vqaddsub),
        Case("vreduce_max.c", "reduce_max_f32",
             lambda rng: (tail_n, _rand(rng, tail_n), np.zeros(1, F)),
             _ref_reduce_max),
        Case("vcvt.c", "cvt_f32_s32",
             lambda rng: (n, _rand(rng, n, -100, 100),
                          np.zeros(n, np.int32)),
             _ref_vcvt),
        Case("vaddl_requant.c", "qs8_vaddl_requant_ukernel",
             lambda rng: (tail_n,
                          rng.integers(-128, 128, tail_n).astype(np.int8),
                          rng.integers(-128, 128, tail_n).astype(np.int8),
                          int(rng.integers(-100, 100)),
                          np.zeros(tail_n, np.uint8)),
             _ref_vaddl_requant),
        Case("vmull_requant.c", "qs8_vmul_requant_ukernel",
             lambda rng: (tail_n,
                          rng.integers(-128, 128, tail_n).astype(np.int8),
                          rng.integers(-128, 128, tail_n).astype(np.int8),
                          np.zeros(tail_n, np.int8)),
             _ref_vmull_requant),
        Case("vmovl_shift.c", "s8_shl1_widen_narrow_ukernel",
             lambda rng: (tail_n,
                          rng.integers(-128, 128, tail_n).astype(np.int8),
                          np.zeros(tail_n, np.int8)),
             _ref_shl1_widen_narrow),
        Case("vcmul.c", "cmul_f32_ukernel",
             lambda rng: (tail_n, _rand(rng, 2 * tail_n),
                          _rand(rng, 2 * tail_n),
                          np.zeros(2 * tail_n, F)),
             _ref_cmul),
        Case("vld3_rgbx.c", "u8_rgbx_deinterleave_ukernel",
             lambda rng: (tail_n,
                          rng.integers(0, 256,
                                       3 * tail_n).astype(np.uint8),
                          np.zeros(tail_n, np.uint8),
                          np.zeros(tail_n, np.uint8),
                          np.zeros(tail_n, np.uint8)),
             _ref_vld3_rgbx),
        Case("vmlal_dot.c", "qs8_vmlal_dot_ukernel",
             lambda rng: (tail_n,
                          rng.integers(-2, 3, tail_n).astype(np.int8),
                          rng.integers(-2, 3, tail_n).astype(np.int8),
                          np.zeros(1, np.int16)),
             _ref_vmlal_dot),
        Case("qs8gemm.c", "qs8_gemm_mx8_ukernel", gemm_args,
             _ref_qs8_gemm),
    ]


def run_differential(n: int = 64, seed: int = 0, target=None,
                     policy: Optional[str] = "pallas",
                     verbose: bool = False) -> Tuple[int, int]:
    """Compile + execute + check every corpus kernel.  Returns
    (checked, total-dynamic-instrs-counted)."""
    from repro import port
    from repro.core import trace

    checked, instrs = 0, 0
    for case in cases(n=n, seed=seed):
        k = port.compile_file(os.path.join(CORPUS_DIR, case.file),
                              name=case.kernel)
        rng = np.random.default_rng(seed + checked)
        args = case.make_args(rng)
        with trace.count() as c:
            got = k(*args, policy=policy, target=target)
        want = case.reference(*args)
        np.testing.assert_allclose(np.asarray(got), want,
                                   rtol=case.rtol, atol=case.atol,
                                   err_msg=f"{case.kernel} diverged from "
                                           f"its NumPy reference")
        checked += 1
        instrs += c["total"]
        if verbose:
            print(f"  {case.kernel:32s} OK   ({c['total']:>5d} instrs)")
    return checked, instrs


if __name__ == "__main__":
    for tgt in (None, "rvv-128"):
        label = tgt or "ambient"
        print(f"# differential corpus run (target={label})")
        k, i = run_differential(verbose=True, target=tgt)
        print(f"# {k} kernels OK, {i} dynamic instructions counted\n")
