"""Differential harness for the NEON corpus.

Each corpus kernel gets (a) an argument builder fixing buffer shapes and
(b) a NumPy reference implementing the *same algorithm* in float32 (not
a looser mathematical ideal), so ported execution must match tightly —
the SIMDe unit-test methodology.  ``run_differential()`` compiles every
``.c`` file, executes it through ``registry.dispatch`` under the given
target/policy, and asserts against the reference.

Run directly:  PYTHONPATH=src python examples/neon_corpus/harness.py
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

CORPUS_DIR = os.path.dirname(os.path.abspath(__file__))

F = np.float32


@dataclasses.dataclass(frozen=True)
class Case:
    file: str
    kernel: str
    make_args: Callable[[np.random.Generator], tuple]
    reference: Callable[..., tuple]
    rtol: float = 1e-6
    atol: float = 1e-6


def _rand(rng, n, lo=-2.0, hi=2.0):
    return rng.uniform(lo, hi, n).astype(F)


# -- reference algorithms (float32 mirrors of the kernels) -------------------

def _tanh_rational(t: np.ndarray) -> np.ndarray:
    t = np.clip(t, F(-4.0), F(4.0))
    t2 = t * t
    p = t2 + F(378.0)
    p = p * t2 + F(17325.0)
    p = p * t2 + F(135135.0)
    p = p * t
    q = t2 * F(28.0) + F(3150.0)
    q = q * t2 + F(62370.0)
    q = q * t2 + F(135135.0)
    r = (F(1.0) / q).astype(F)
    r = r * (F(2.0) - q * r)
    r = r * (F(2.0) - q * r)
    return (p * r).astype(F)


def _ref_vadd(n, a, b, y):
    out = y.copy()
    out[:n] = a[:n] + b[:n]
    return out


def _ref_vmul(n, a, b, y):
    out = y.copy()
    out[:n] = a[:n] * b[:n]
    return out


def _ref_vmulcaddc(n, x, scale, bias, y):
    out = y.copy()
    m = (n // 4) * 4
    k = m // 4
    out[:m] = x[:m] * np.tile(scale, k) + np.tile(bias, k)
    return out


def _ref_vclamp(n, x, y, lo, hi):
    out = y.copy()
    out[:n] = np.clip(x[:n], F(lo), F(hi))
    return out


def _ref_vtanh(n, x, y):
    out = y.copy()
    m = (n // 4) * 4
    out[:m] = _tanh_rational(x[:m])
    return out


def _ref_vsigmoid(n, x, y):
    out = y.copy()
    m = (n // 4) * 4
    th = _tanh_rational((x[:m] * F(0.5)).astype(F))
    out[:m] = F(0.5) + th * F(0.5)
    return out


def _ref_vdot(n, a, b, sum_buf):
    m = (n // 4) * 4
    acc = np.zeros(4, F)
    for i in range(0, m, 4):
        acc = acc + a[i:i + 4] * b[i:i + 4]
    s = F(acc.sum())
    for i in range(m, n):
        s = F(s + a[i] * b[i])
    out = sum_buf.copy()
    out[0] = s
    return out


def _ref_vrsqrt(n, x, y):
    out = y.copy()
    m = (n // 4) * 4
    v = x[:m]
    r = (F(1.0) / np.sqrt(v)).astype(F)
    r = r * ((F(3.0) - (v * r) * r) * F(0.5))
    r = r * ((F(3.0) - (v * r) * r) * F(0.5))
    out[:m] = r
    return out


def _ref_vfold(n, x, y):
    out = y.copy()
    m = (n // 4) * 4
    q = x[:m].reshape(-1, 4)
    out[:m // 2] = (q[:, 2:] + q[:, :2]).reshape(-1)
    return out


def _ref_vselect(n, x, y):
    out = y.copy()
    m = (n // 4) * 4
    out[:m] = np.where(x[:m] > 0, x[:m], F(0.0))
    return out


def _ref_vrbit(n, x, y):
    out = y.copy()
    m = (n // 16) * 16
    v = x[:m]
    v = ((v >> 1) & 0x55) | ((v & 0x55) << 1)
    v = ((v >> 2) & 0x33) | ((v & 0x33) << 2)
    v = ((v >> 4) & 0x0F) | ((v & 0x0F) << 4)
    out[:m] = v
    return out


def _ref_vqaddsub(n, a, b, ya, ys):
    outa, outs = ya.copy(), ys.copy()
    s = np.clip(a[:n].astype(np.int32) + b[:n].astype(np.int32), -128, 127)
    d = np.clip(a[:n].astype(np.int32) - b[:n].astype(np.int32), -128, 127)
    outa[:n] = (s + 128).astype(np.uint8)
    outs[:n] = (d + 128).astype(np.uint8)
    return outa, outs


def _ref_reduce_max(n, x, out_buf):
    out = out_buf.copy()
    out[0] = np.max(x[:n])
    return out


def _ref_vcvt(n, x, y):
    out = y.copy()
    m = (n // 4) * 4
    out[:m] = x[:m].astype(np.int32)    # C truncation semantics
    return out


# -- the corpus ---------------------------------------------------------------

def cases(n: int = 64, tail_n: int = 67, seed: int = 0) -> Sequence[Case]:
    """``n`` drives strip-only kernels (multiple of 16); ``tail_n`` the
    kernels with scalar tails (deliberately not a multiple of 4)."""
    assert n % 16 == 0, "n must be a multiple of 16 (vrbit strips)"

    def args_abn(rng):     # (n, a, b, y) with tail
        return (tail_n, _rand(rng, tail_n), _rand(rng, tail_n),
                np.zeros(tail_n, F))

    return [
        Case("vadd.c", "xnn_f32_vadd_ukernel", args_abn, _ref_vadd),
        Case("vmul.c", "xnn_f32_vmul_ukernel", args_abn, _ref_vmul),
        Case("vmulcaddc.c", "xnn_f32_vmulcaddc_ukernel_c4",
             lambda rng: (n, _rand(rng, n), _rand(rng, 4, 0.5, 1.5),
                          _rand(rng, 4), np.zeros(n, F)),
             _ref_vmulcaddc),
        Case("vclamp.c", "xnn_f32_vclamp_ukernel",
             lambda rng: (tail_n, _rand(rng, tail_n, -3, 3),
                          np.zeros(tail_n, F), -1.0, 1.5),
             _ref_vclamp),
        Case("vtanh.c", "xnn_f32_vtanh_ukernel",
             lambda rng: (n, _rand(rng, n, -6, 6), np.zeros(n, F)),
             _ref_vtanh, rtol=2e-5, atol=1e-6),
        Case("vsigmoid.c", "xnn_f32_vsigmoid_ukernel",
             lambda rng: (n, _rand(rng, n, -8, 8), np.zeros(n, F)),
             _ref_vsigmoid, rtol=2e-5, atol=1e-6),
        Case("vdot.c", "xnn_f32_vdot_ukernel",
             lambda rng: (tail_n, _rand(rng, tail_n), _rand(rng, tail_n),
                          np.zeros(1, F)),
             _ref_vdot, rtol=1e-5),
        Case("vrsqrt.c", "xnn_f32_vrsqrt_ukernel",
             lambda rng: (n, _rand(rng, n, 0.01, 9.0), np.zeros(n, F)),
             _ref_vrsqrt, rtol=1e-5),
        Case("vfold.c", "fold_halves_f32",
             lambda rng: (n, _rand(rng, n), np.zeros(n // 2, F)),
             _ref_vfold),
        Case("vselect.c", "relu_bsl_f32",
             lambda rng: (n, _rand(rng, n), np.zeros(n, F)),
             _ref_vselect),
        Case("vrbit.c", "bitreverse_u8",
             lambda rng: (n, rng.integers(0, 256, n).astype(np.uint8),
                          np.zeros(n, np.uint8)),
             _ref_vrbit),
        Case("vqaddsub.c", "qs8_vaddsub_biased_ukernel",
             lambda rng: (tail_n,
                          rng.integers(-128, 128, tail_n).astype(np.int8),
                          rng.integers(-128, 128, tail_n).astype(np.int8),
                          np.zeros(tail_n, np.uint8),
                          np.zeros(tail_n, np.uint8)),
             _ref_vqaddsub),
        Case("vreduce_max.c", "reduce_max_f32",
             lambda rng: (tail_n, _rand(rng, tail_n), np.zeros(1, F)),
             _ref_reduce_max),
        Case("vcvt.c", "cvt_f32_s32",
             lambda rng: (n, _rand(rng, n, -100, 100),
                          np.zeros(n, np.int32)),
             _ref_vcvt),
    ]


def run_differential(n: int = 64, seed: int = 0, target=None,
                     policy: Optional[str] = "pallas",
                     verbose: bool = False) -> Tuple[int, int]:
    """Compile + execute + check every corpus kernel.  Returns
    (checked, total-dynamic-instrs-counted)."""
    from repro import port
    from repro.core import trace

    checked, instrs = 0, 0
    for case in cases(n=n, seed=seed):
        k = port.compile_file(os.path.join(CORPUS_DIR, case.file),
                              name=case.kernel)
        rng = np.random.default_rng(seed + checked)
        args = case.make_args(rng)
        with trace.count() as c:
            got = k(*args, policy=policy, target=target)
        want = case.reference(*args)
        np.testing.assert_allclose(np.asarray(got), want,
                                   rtol=case.rtol, atol=case.atol,
                                   err_msg=f"{case.kernel} diverged from "
                                           f"its NumPy reference")
        checked += 1
        instrs += c["total"]
        if verbose:
            print(f"  {case.kernel:32s} OK   ({c['total']:>5d} instrs)")
    return checked, instrs


if __name__ == "__main__":
    for tgt in (None, "rvv-128"):
        label = tgt or "ambient"
        print(f"# differential corpus run (target={label})")
        k, i = run_differential(verbose=True, target=tgt)
        print(f"# {k} kernels OK, {i} dynamic instructions counted\n")
