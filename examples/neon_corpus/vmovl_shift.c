/* Widen / operate / truncating-narrow round trip (vmovl -> vsext.vf2,
 * vmovn -> vncvt): y[i] = (int8) (((int16) x[i]) << 1), wrapping —
 * the non-saturating narrow keeps only the low byte.                  */
#include <arm_neon.h>

void s8_shl1_widen_narrow_ukernel(size_t n, const int8_t* x, int8_t* y) {
  for (; n >= 8; n -= 8) {
    int16x8_t vx = vmovl_s8(vld1_s8(x)); x += 8;
    vx = vshlq_n_s16(vx, 1);
    vst1_s8(y, vmovn_s16(vx)); y += 8;
  }
  for (; n != 0; n -= 1) {
    int32_t t = ((int32_t) *x) << 1; x += 1;
    t = t & 255;
    t = t > 127 ? t - 256 : t;
    *y = (int8_t) t; y += 1;
  }
}
