/* Widening qs8 add with unsigned requantization — the XNNPACK qs8-vadd
 * shape on the widening path (paper Table 2's vaddl/vqmovun rows):
 *   y[i] = sat_u8((int16) a[i] + (int16) b[i] + bias)
 * vaddl_s8 is RVV's single vwadd.vv; vqmovun_s16 a single vnclipu.
 * |bias| stays small enough that the int16 accumulator is exact.      */
#include <arm_neon.h>

void qs8_vaddl_requant_ukernel(size_t n, const int8_t* a, const int8_t* b,
                               int32_t bias, uint8_t* y) {
  const int16x8_t vbias = vdupq_n_s16((int16_t) bias);
  for (; n >= 8; n -= 8) {
    int8x8_t va = vld1_s8(a); a += 8;
    int8x8_t vb = vld1_s8(b); b += 8;
    int16x8_t vacc = vaddl_s8(va, vb);
    vacc = vaddq_s16(vacc, vbias);
    vst1_u8(y, vqmovun_s16(vacc)); y += 8;
  }
  for (; n != 0; n -= 1) {
    int32_t s = (int32_t) *a + (int32_t) *b + bias;
    a += 1; b += 1;
    s = s > 255 ? 255 : s;
    s = s < 0 ? 0 : s;
    *y = (uint8_t) s; y += 1;
  }
}
