/* XNNPACK-style f32 sigmoid contraction via the tanh rational:
 * sigmoid(x) = 0.5 + 0.5 * tanh(x/2), same vfma-ladder + vrecpe/vrecps
 * structure as vtanh.c (paper Figure 2's other largest win). */
#include <arm_neon.h>

void xnn_f32_vsigmoid_ukernel(size_t n, const float* x, float* y) {
  const float32x4_t vhalf = vdupq_n_f32(0.5f);
  const float32x4_t vclamp = vdupq_n_f32(4.0f);
  const float32x4_t vnclamp = vdupq_n_f32(-4.0f);
  const float32x4_t c135135 = vdupq_n_f32(135135.0f);
  const float32x4_t c17325 = vdupq_n_f32(17325.0f);
  const float32x4_t c378 = vdupq_n_f32(378.0f);
  const float32x4_t c62370 = vdupq_n_f32(62370.0f);
  const float32x4_t c3150 = vdupq_n_f32(3150.0f);
  const float32x4_t c28 = vdupq_n_f32(28.0f);
  for (; n >= 4; n -= 4) {
    float32x4_t vx = vld1q_f32(x); x += 4;
    float32x4_t vt = vmulq_f32(vx, vhalf);
    vt = vminq_f32(vmaxq_f32(vt, vnclamp), vclamp);
    float32x4_t vt2 = vmulq_f32(vt, vt);
    float32x4_t vp = vaddq_f32(vt2, c378);
    vp = vfmaq_f32(c17325, vp, vt2);
    vp = vfmaq_f32(c135135, vp, vt2);
    vp = vmulq_f32(vp, vt);
    float32x4_t vq = vfmaq_f32(c3150, vt2, c28);
    vq = vfmaq_f32(c62370, vq, vt2);
    vq = vfmaq_f32(c135135, vq, vt2);
    float32x4_t vr = vrecpeq_f32(vq);
    vr = vmulq_f32(vr, vrecpsq_f32(vq, vr));
    vr = vmulq_f32(vr, vrecpsq_f32(vq, vr));
    float32x4_t vth = vmulq_f32(vp, vr);
    vst1q_f32(y, vfmaq_f32(vhalf, vth, vhalf)); y += 4;
  }
}
