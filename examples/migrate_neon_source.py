"""Migration walkthrough: compile real NEON intrinsic source with the
port frontend, run it, and read the per-intrinsic analysis — the
paper's end-to-end task in four calls.

  PYTHONPATH=src python examples/migrate_neon_source.py
"""
import os

import numpy as np

from repro import port

CORPUS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "neon_corpus")

# 1. compile legacy source: C NEON -> typed SSA -> logical ISA
kernel = port.compile_file(os.path.join(CORPUS, "vtanh.c"))
print(f"compiled {kernel!r}\n")

# 2. execute: every intrinsic dispatches through the cost-driven
#    selector; outputs are the written buffers
n = 64
x = np.linspace(-5, 5, n, dtype=np.float32)
y = np.asarray(kernel(n, x, np.zeros(n, np.float32)))
err = np.max(np.abs(y - np.tanh(x)))
print(f"ported vtanh on {n} lanes: max |err| vs np.tanh = {err:.2e}\n")

# 3. Table 2 for this kernel: which register types map at vlen=64?
sub = kernel.substitution("rvv-64")
unmapped = [name for name, ok in sub.items() if not ok]
print(f"rvv-64 substitution: {len(unmapped)}/{len(sub)} intrinsics fall "
      f"back to the scalar loop\n")

# 4. the migration report: per-intrinsic tier + dynamic instruction
#    estimates across the RVV width family
rep = port.report(kernel, n, x, np.zeros(n, np.float32))
print(port.format_report(rep))
