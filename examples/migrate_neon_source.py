"""Migration walkthrough: compile real NEON intrinsic source with the
port frontend, run it, JIT it through the re-vectorizing backend, and
read the per-intrinsic analysis — the paper's end-to-end task, then the
step past it (SIMDe stays 128-bit; ``compile(revec=True)`` doesn't).

  PYTHONPATH=src python examples/migrate_neon_source.py
"""
import os
import time

import numpy as np

from repro import port

CORPUS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "neon_corpus")

# 1. compile legacy source: C NEON -> typed SSA -> logical ISA
kernel = port.compile_file(os.path.join(CORPUS, "vtanh.c"))
print(f"compiled {kernel!r}\n")

# 2. execute: every intrinsic dispatches through the cost-driven
#    selector; outputs are the written buffers
n = 64
x = np.linspace(-5, 5, n, dtype=np.float32)
y = np.asarray(kernel(n, x, np.zeros(n, np.float32)))
err = np.max(np.abs(y - np.tanh(x)))
print(f"ported vtanh on {n} lanes: max |err| vs np.tanh = {err:.2e}\n")

# 3. Table 2 for this kernel: which register types map at vlen=64?
sub = kernel.substitution("rvv-64")
unmapped = [name for name, ok in sub.items() if not ok]
print(f"rvv-64 substitution: {len(unmapped)}/{len(sub)} intrinsics fall "
      f"back to the scalar loop\n")

# 4. the migration report: per-intrinsic tier + dynamic instruction
#    estimates across the RVV width family, with the re-vectorized
#    column (strips re-tiled at VLEN x LMUL) that finally diverges
rep = port.report(kernel, n, x, np.zeros(n, np.float32), compiled=True)
print(port.format_report(rep))

# 5. the JIT backend: the interpreter issues one Python dispatch per
#    strip; compile() lowers the whole kernel to a single jitted XLA
#    loop, and revec=True re-tiles it at the target register width
n = 4096
x = np.linspace(-5, 5, n, dtype=np.float32)
t0 = time.perf_counter()
kernel(n, x, np.zeros(n, np.float32), target="rvv-128")
t_interp = time.perf_counter() - t0

jitted = kernel.compile(target="rvv-1024", revec=True)
print(f"\n{jitted!r}")
for note in jitted.retiling.notes:
    print(f"  - {note}")
np.asarray(jitted(n, x, np.zeros(n, np.float32)))     # compile + warmup
t0 = time.perf_counter()
y2 = np.asarray(jitted(n, x, np.zeros(n, np.float32)))
t_jit = time.perf_counter() - t0
print(f"\nwall clock at n={n}: interpreter {t_interp*1e3:.1f} ms, "
      f"compiled+revec {t_jit*1e3:.3f} ms "
      f"({t_interp/t_jit:,.0f}x)")
assert np.max(np.abs(y2 - np.tanh(x))) < 1e-3
