"""Quickstart: the paper's technique in 30 lines.

Port a NEON-intrinsics computation through the lowering ladder, compare
the tiers, and count dynamic instructions (the paper's Figure-2 metric).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import isa, trace, use_policy
from repro.kernels import ops

# --- 1. NEON-style code against the portable ISA (paper Listing 9) -------
a = jnp.arange(16, dtype=jnp.int32)
b = jnp.full(16, 3, jnp.int32)
print("vaddq_s32 ->", isa.vadd(a, b)[:8], "...")
print("vrbit     ->", isa.vrbit(jnp.asarray([1, 2, 128], jnp.uint8)))

# --- 2. the conversion ladder: same op, three lowerings -------------------
x = jax.random.normal(jax.random.PRNGKey(0), (512, 512))
outs = {}
for tier in ("generic", "vector", "pallas"):
    with use_policy(tier):
        outs[tier] = ops.vtanh(x)
np.testing.assert_allclose(np.asarray(outs["vector"]),
                           np.asarray(outs["pallas"]), rtol=1e-5, atol=2e-6)
print("all lowering tiers agree on vtanh")

# --- 3. dynamic instruction counts (the paper's Spike methodology) --------
with trace.cost_target("rvv-128"):         # the paper's vector width
    base = trace.jaxpr_vector_instrs(lambda v: jnp.tanh(v), x,
                                     scalarize=True, union_overhead=True)
    with trace.count() as c:
        with use_policy("pallas"):
            ops.vtanh(x)
    cust = c["total"]
print(f"vtanh dynamic instrs: baseline={base} customized={cust} "
      f"speedup={base / cust:.2f}x (paper Figure 2: 1.51x-5.13x)")

# --- 4. a fused GEMM through the MXU-tiled kernel --------------------------
m = jax.random.normal(jax.random.PRNGKey(1), (256, 256))
w = jax.random.normal(jax.random.PRNGKey(2), (256, 256))
with use_policy("pallas"):
    y = ops.gemm(m, w, clamp_min=-1.0, clamp_max=1.0)
print("fused gemm+clamp:", y.shape, "max", float(jnp.max(y)))
