"""End-to-end driver: train an LM for a few hundred steps.

Default is a fast reduced config; ``--preset 100m`` trains a ~100M-param
gemma2-family model (a few hundred steps is hours on this CPU container;
on TPU it is the same code under a production mesh via launch/train.py).

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import logging

from repro.configs import get_config
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainConfig, train


def preset_cfg(name: str):
    base = get_config("gemma2-2b")
    if name == "reduced":
        return base.reduced(), 8, 128
    if name == "100m":
        # ~100M params: 12L d=768 ff=3072 vocab=32k
        return base.replace(
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
            d_ff=3072, vocab_size=32_000, local_global=(1, 1), window=512,
            sandwich_norm=False, softcap=None, final_softcap=None), 4, 512
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("reduced", "100m"),
                    default="reduced")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    cfg, batch, seq = preset_cfg(args.preset)
    total, _ = cfg.param_counts()
    print(f"training {cfg.name} [{args.preset}]: {total / 1e6:.1f}M params, "
          f"batch={batch} seq={seq} steps={args.steps}")
    res = train(cfg, steps=args.steps, batch_size=batch, seq_len=seq,
                tcfg=TrainConfig(optim=AdamWConfig(
                    lr=3e-4, warmup_steps=20, total_steps=args.steps)),
                ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10)
    hist = res["history"]
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
          f"over {len(hist)} steps "
          f"(restarts={res['restarts']}, stragglers={len(res['watchdog'])})")


if __name__ == "__main__":
    main()
