"""Serving example: batched prefill + decode with per-arch caches.

Runs three cache families: GQA ring-buffer (gemma), SSM state (mamba2),
and MLA compressed cache (deepseek) — same Engine API.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import Engine

for arch in ("gemma2-2b", "mamba2-1.3b", "deepseek-v2-lite-16b"):
    cfg = get_config(arch).reduced()
    params = M.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=4, max_seq=96)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 2,
                                 cfg.vocab_size)
    t0 = time.time()
    out = eng.generate(prompts, 24)
    dt = time.time() - t0
    print(f"{arch:24s} generated {out.shape[0]}x{out.shape[1]} tokens "
          f"in {dt:.2f}s ({out.shape[0] * out.shape[1] / dt:.0f} tok/s) "
          f"first row: {out[0][:8].tolist()}")
