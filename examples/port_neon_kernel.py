"""Porting walkthrough: add a NEW customized lowering to the registry.

The paper's §3.3 workflow: start from the generic conversion, inspect
the generated code, write a customized implementation, validate, and
measure.  Here we port NEON's ``vcnt`` (population count) — not in the
shipped ISA — end to end.

  PYTHONPATH=src python examples/port_neon_kernel.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import registry, trace, use_policy
from repro.core.registry import register

# --- 1. generic conversion (always-valid oracle: scalar bit loop) ---------


@register("vcnt", "generic", cost=trace.scalar_cost(8))
def _vcnt_generic(a):
    def cnt1(x):
        x = x.astype(jnp.uint8)
        total = jnp.zeros((), jnp.uint8)
        for i in range(8):
            total = total + ((x >> i) & jnp.uint8(1))
        return total
    return jax.vmap(cnt1)(jnp.ravel(a)).reshape(a.shape).astype(a.dtype)


# --- 2. customized conversion: SWAR popcount (binary magic numbers, the
#        same Freed/Dr.Dobb's playbook as the paper's vrbit Listing 7) ----


@register("vcnt", "pallas", cost=trace.vector_cost(8),
          doc="SWAR popcount: x - ((x>>1)&0x55); nibble fold; *0x01 fold")
def _vcnt_custom(a):
    x = a.astype(jnp.uint8)
    x = x - ((x >> 1) & jnp.uint8(0x55))
    x = (x & jnp.uint8(0x33)) + ((x >> 2) & jnp.uint8(0x33))
    x = (x + (x >> 4)) & jnp.uint8(0x0F)
    return x.astype(a.dtype)


def vcnt(a):
    return registry.dispatch("vcnt", a)


# --- 3. validate tiers against each other (the SIMDe unit-test workflow) --
x = jax.random.randint(jax.random.PRNGKey(0), (4096,), 0, 256,
                       dtype=jnp.int32).astype(jnp.uint8)
with use_policy("generic"):
    want = vcnt(x)
got = vcnt(x)  # default policy -> customized
np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
print("vcnt: customized lowering matches the generic oracle on 4096 lanes")

# --- 4. measure (dynamic instruction counts, both cost targets) -----------
for target, label in (("rvv-128", "RVV-128"), ("tpu-v5e", "TPU v5e")):
    with trace.cost_target(target):
        with trace.count() as c_base:
            with use_policy("generic"):
                vcnt(x)
        with trace.count() as c_cust:
            vcnt(x)
    print(f"{label:8s}: baseline={c_base['total']:>6d} "
          f"customized={c_cust['total']:>4d} "
          f"speedup={c_base['total'] / c_cust['total']:.1f}x")
