"""NEON-corpus migration sweep + the JIT backend's wall-clock suite.

Two measurements per corpus kernel:

* **dynamic vector instructions** (the paper's Spike methodology) across
  the RVV width family — baseline (original-SIMDe ``vector`` policy cap)
  vs cost-driven selection vs the **re-vectorized** form
  (``port.revec``: strips re-tiled at VLEN x LMUL with predicated
  tails).  The fixed-width port costs the same from rvv-128 to rvv-1024
  — exactly SIMDe's fixed-vlen limitation; the re-tiled column finally
  diverges, shrinking with the register.
* **wall clock** — interpreter (one Python dispatch per strip) vs the
  compiled path (``port.compile``: one jitted ``fori_loop``) vs compiled
  + re-vectorized, at a serving-realistic buffer size.

  PYTHONPATH=src python benchmarks/port_suite.py          # writes BENCH_port.json
  PYTHONPATH=src python benchmarks/port_suite.py --check  # + regression gate
                                                          #   vs committed JSON
  PYTHONPATH=src python benchmarks/port_suite.py --coverage-gate
                                # cheap re-tile coverage check vs the
                                # committed JSON (no XLA, no wall clock)
"""
from __future__ import annotations

import json
import math
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CORPUS = os.path.join(ROOT, "examples", "neon_corpus")
sys.path.insert(0, CORPUS)

import harness  # noqa: E402  (the corpus differential harness)

from repro import port  # noqa: E402

# PORT_SWEEP plus the LMUL=2 grouping column
SWEEP = ("rvv-64", "rvv-64-m2", "rvv-128", "rvv-256", "rvv-512",
         "rvv-1024")

# the paper's customized-conversion showcases (Listings 5/6/7): the
# cost-driven selection must beat the original-SIMDe ladder baseline
LISTING_KERNELS = ("fold_halves_f32", "relu_bsl_f32", "bitreverse_u8")
# simple arithmetic keeps the vector tier — no win to be had (Listing 8)
ARITH_KERNELS = ("xnn_f32_vadd_ukernel", "xnn_f32_vmul_ukernel")
# strip-pattern kernels the re-vectorizer must widen on rvv-1024
# (fold_halves is the deliberate counter-example: vget_high/low
# cross-lane structure keeps it at NEON granularity).  The qs8 gemm
# microkernel used to sit here too — per-site offset re-tiling now
# widens its inner dot-product strip while the outer row loop stays a
# recorded narrow fallback.
UNSCALABLE = ("fold_halves_f32",)
# kernels whose strips nest inside a scalar outer loop: the inner strip
# re-tiles, the outer loop is a *structural* narrow fallback the report
# must carry (not a silent one)
NESTED = ("qs8_gemm_mx8_ukernel", "f32_rowscale_ukernel")
# width-changing strips re-tile by the *narrow* side (lane groups): an
# 8-lane s8 D register has 16x headroom on rvv-1024, not the f32 8x
WIDENING_16 = ("qs8_vaddl_requant_ukernel", "qs8_vmul_requant_ukernel",
               "s8_shl1_widen_narrow_ukernel", "qs8_vmlal_dot_ukernel",
               "qs8_gemm_mx8_ukernel")

# wall-clock suite geometry: large enough that the interpreter's
# per-strip Python dispatch dominates, small enough to keep CI honest
WALL_N, WALL_TAIL_N = 2048, 2051


def sweep_corpus(n=64, seed=0):
    """port.report (with the revec column) for every corpus kernel."""
    import numpy as np
    out = {}
    for i, case in enumerate(harness.cases(n=n)):
        k = port.compile_file(os.path.join(CORPUS, case.file),
                              name=case.kernel)
        rng = np.random.default_rng(seed + i)
        args = case.make_args(rng)
        out[case.kernel] = port.report(k, *args, sweep=SWEEP,
                                       compiled=True)
    return out


def bench_wall(seed=0, repeats=10):
    """Wall-clock per kernel: interpreter vs compiled vs compiled+revec.

    The interpreter runs under rvv-128 (the ported fixed width); the
    compiled path under the same target; the re-vectorized path under
    rvv-1024 (where re-tiling actually widens the strips).
    """
    import numpy as np
    rows = {}
    for i, case in enumerate(harness.cases(n=WALL_N, tail_n=WALL_TAIL_N)):
        k = port.compile_file(os.path.join(CORPUS, case.file),
                              name=case.kernel)
        rng = np.random.default_rng(seed + i)
        args = case.make_args(rng)

        t0 = time.perf_counter()
        ref_out = k(*args, target="rvv-128")
        t_interp = time.perf_counter() - t0

        def timed(fn):
            outs = fn(*args)                      # compile + warmup
            _block(outs)
            best = math.inf
            for _ in range(repeats):
                t = time.perf_counter()
                outs = fn(*args)
                _block(outs)
                best = min(best, time.perf_counter() - t)
            return outs, best

        comp = k.compile(target="rvv-128")
        out_c, t_comp = timed(comp)
        _assert_close(out_c, ref_out, case)

        rev = k.compile(target="rvv-1024", revec=True)
        out_r, t_rev = timed(rev)
        _assert_close(out_r, case.reference(*args), case)

        rows[case.kernel] = {
            "n": WALL_N,
            "interp_ms": round(t_interp * 1e3, 3),
            "compiled_ms": round(t_comp * 1e3, 4),
            "revec_ms": round(t_rev * 1e3, 4),
            "compiled_speedup": round(t_interp / t_comp, 1),
            "revec_speedup": round(t_interp / t_rev, 1),
            "retile_factor": (rev.retiling.factor
                              if rev.retiling is not None else 1),
        }
    return rows


def _block(outs):
    import numpy as np
    if isinstance(outs, tuple):
        for o in outs:
            np.asarray(o)
    else:
        np.asarray(outs)


def _assert_close(got, want, case):
    import numpy as np
    got = got if isinstance(got, tuple) else (got,)
    want = want if isinstance(want, tuple) else (want,)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=max(case.rtol, 1e-5),
                                   atol=max(case.atol, 1e-6),
                                   err_msg=f"{case.kernel}: compiled "
                                           f"path diverged")


def check(reports, wall=None):
    """Acceptance properties of the migration sweep."""
    assert len(reports) >= 20, f"corpus shrank to {len(reports)} kernels"
    for name in LISTING_KERNELS:
        rep = reports[name]["targets"]["rvv-128"]
        assert rep["speedup"] > 1.0, \
            f"{name}: customized conversion not cheaper ({rep['speedup']}x)"
    for name in ARITH_KERNELS:
        rep = reports[name]["targets"]["rvv-128"]
        assert abs(rep["speedup"] - 1.0) < 1e-9, \
            f"{name}: simple arithmetic should keep the vector tier"
    # Table-2 'x' entries: on rvv-64 every Q-register intrinsic falls
    # back; LMUL=2 grouping restores the native mapping
    vadd = reports["xnn_f32_vadd_ukernel"]
    assert not vadd["targets"]["rvv-64"]["maps"]["vaddq_f32"]
    assert vadd["targets"]["rvv-64-m2"]["maps"]["vaddq_f32"]
    assert vadd["targets"]["rvv-64"]["total_instrs"] > \
        vadd["targets"]["rvv-128"]["total_instrs"]

    # the re-vectorizer: rvv-1024 must finally diverge from rvv-128
    for name, rep in reports.items():
        r1024 = rep["targets"]["rvv-1024"]["revec"]
        if name in UNSCALABLE:
            assert r1024["factor"] == 1, \
                f"{name}: unscalable kernel must not re-tile"
            assert r1024["vetoes"], \
                f"{name}: narrow fallback must carry a structured veto"
            continue
        r128 = rep["targets"]["rvv-128"]["revec"]
        want = 16 if name in WIDENING_16 else 8
        assert r1024["factor"] == want, \
            f"{name}: expected {want}x re-tile on rvv-1024, got " \
            f"{r1024['factor']}x"
        assert r1024["retiled"] >= 1, f"{name}: no strip re-tiled"
        if name in NESTED:
            # the scalar outer loop is an *accounted* fallback
            assert r1024["narrow_fallbacks"] >= 1 and r1024["vetoes"], \
                f"{name}: nested outer loop must be a recorded veto"
        else:
            assert r1024["narrow_fallbacks"] == 0, \
                f"{name}: unexpected narrow fallback " \
                f"({r1024['vetoes']})"
        assert r1024["total_instrs"] < r128["total_instrs"], \
            f"{name}: rvv-1024 should beat rvv-128 after re-tiling"
        assert r1024["total_instrs"] * 2 <= r128["total_instrs"], \
            f"{name}: rvv-1024 re-tile only " \
            f"{r128['total_instrs'] / max(1, r1024['total_instrs']):.2f}x " \
            f"under rvv-128 (want >= 2x)"

    if wall is not None:
        speedups = [row["compiled_speedup"] for row in wall.values()]
        geomean = math.exp(sum(math.log(s) for s in speedups)
                           / len(speedups))
        assert geomean >= 10.0, \
            f"compiled path geomean speedup {geomean:.1f}x < 10x"
        assert min(speedups) >= 5.0, \
            f"slowest compiled kernel only {min(speedups):.1f}x"


def check_wall_instrs(reports, n=WALL_N, tail_n=WALL_TAIL_N, seed=0):
    """At serving size, re-tiled rvv-1024 must retire >= 4x fewer
    dynamic vector instructions than the fixed-128-bit port for every
    scalable strip kernel (the ISSUE-3 acceptance bar).  Returns
    {kernel: ratio}."""
    import numpy as np
    ratios = {}
    for i, case in enumerate(harness.cases(n=n, tail_n=tail_n)):
        if case.kernel in UNSCALABLE:
            continue
        k = port.compile_file(os.path.join(CORPUS, case.file),
                              name=case.kernel)
        rng = np.random.default_rng(seed + i)
        args = case.make_args(rng)
        fixed = k.estimate(*args, target="rvv-1024")["total_instrs"]
        rev = k.compile(target="rvv-1024", revec=True).estimate(
            *args)["total_instrs"]
        ratios[case.kernel] = round(fixed / max(1, rev), 2)
        assert ratios[case.kernel] >= 4.0, \
            f"{case.kernel}: re-tiled rvv-1024 only " \
            f"{ratios[case.kernel]}x fewer instrs (want >= 4x)"
    return ratios


def emit_json(reports, wall=None, instr_ratios=None,
              path="BENCH_port.json"):
    data = {"suite": "neon_port_corpus",
            "metric": "dynamic_vector_instructions",
            "sweep": list(SWEEP),
            "kernels": {}}
    for name, rep in sorted(reports.items()):
        data["kernels"][name] = {
            "intrinsics": {
                i: {"sites": m["sites"], "isa_op": m["isa_op"],
                    "width_bits": m["width_bits"]}
                for i, m in sorted(rep["intrinsics"].items())},
            "targets": {
                t: {"total_instrs": row["total_instrs"],
                    "baseline_instrs": row["baseline_total_instrs"],
                    "scalar_instrs": row["scalar_instrs"],
                    "speedup": row["speedup"],
                    "revec_instrs": row["revec"]["total_instrs"],
                    "retile_factor": row["revec"]["factor"],
                    "masked_tails": row["revec"]["masked"],
                    "strips": row["revec"]["strips"],
                    "retiled_strips": row["revec"]["retiled"],
                    "narrow_fallbacks": row["revec"]["narrow_fallbacks"],
                    "vetoes": row["revec"]["vetoes"],
                    "unmapped": sorted(i for i, ok in row["maps"].items()
                                       if not ok)}
                for t, row in rep["targets"].items()},
        }
        if wall and name in wall:
            data["kernels"][name]["wall"] = wall[name]
        if instr_ratios and name in instr_ratios:
            data["kernels"][name]["revec_instr_ratio_rvv1024"] = \
                instr_ratios[name]
    data["retile_coverage"] = retile_coverage(data["kernels"])
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    return path


def retile_coverage(kernels, target="rvv-1024"):
    """The suite-level coverage fact the CI gate compares: which
    kernels re-tile at the widest target, and how many strips per
    kernel still fall back narrow."""
    retiled = sorted(n for n, k in kernels.items()
                     if k["targets"][target]["retile_factor"] > 1)
    return {
        "target": target,
        "retiled_kernels": len(retiled),
        "total_kernels": len(kernels),
        "retiled": retiled,
        "narrow_fallbacks": {
            n: k["targets"][target]["narrow_fallbacks"]
            for n, k in sorted(kernels.items())
            if k["targets"][target]["narrow_fallbacks"]},
    }


def check_regression(data, baseline_path="BENCH_port.json",
                     wall_slack=0.25):
    """Fail if the fresh run regresses against the committed baseline:
    instruction counts may not grow, and wall-clock speedups may not
    collapse (CI machines vary, so wall gets ``wall_slack`` headroom on
    top of the absolute >= 10x floor asserted by :func:`check`)."""
    if not os.path.exists(baseline_path):
        print(f"# no committed {baseline_path}; skipping regression gate")
        return
    with open(baseline_path) as f:
        base = json.load(f)
    problems = []
    for name, krow in base.get("kernels", {}).items():
        fresh = data["kernels"].get(name)
        if fresh is None:
            problems.append(f"{name}: kernel disappeared from the corpus")
            continue
        for t, row in krow.get("targets", {}).items():
            frow = fresh["targets"].get(t)
            if frow is None:
                continue
            for key in ("total_instrs", "revec_instrs"):
                if key in row and frow[key] > row[key]:
                    problems.append(
                        f"{name}/{t}: {key} {row[key]} -> {frow[key]}")
            # re-tile coverage may only grow: a kernel that re-tiled at
            # the committed baseline must not fall back narrow again
            if "retile_factor" in row and \
                    frow["retile_factor"] < row["retile_factor"]:
                problems.append(
                    f"{name}/{t}: retile factor regressed "
                    f"{row['retile_factor']}x -> {frow['retile_factor']}x")
            if row.get("narrow_fallbacks") is not None and \
                    frow.get("narrow_fallbacks", 0) > \
                    row["narrow_fallbacks"]:
                problems.append(
                    f"{name}/{t}: narrow fallbacks grew "
                    f"{row['narrow_fallbacks']} -> "
                    f"{frow['narrow_fallbacks']} "
                    f"({[v['reason'] for v in frow.get('vetoes', [])]})")
        if "wall" in krow and "wall" in fresh:
            floor = max(10.0, row_speedup(krow) * wall_slack)
            got = row_speedup(fresh)
            if got < floor:
                problems.append(
                    f"{name}: compiled wall speedup {got:.0f}x below "
                    f"floor {floor:.0f}x")
    if problems:
        raise AssertionError("BENCH_port regression vs committed "
                             "baseline:\n  " + "\n  ".join(problems))
    print(f"# regression gate vs {baseline_path}: OK")


def row_speedup(krow):
    return float(krow["wall"]["compiled_speedup"])


def coverage_gate(baseline_path="BENCH_port.json", target="rvv-1024"):
    """Cheap CI gate (no XLA compiles, no wall clock): recompute each
    corpus kernel's re-tile structure and fail if coverage dropped
    below the committed BENCH_port.json — a kernel that re-tiled at the
    seed silently falling back narrow is exactly the regression this
    PR exists to stop."""
    if not os.path.exists(baseline_path):
        raise AssertionError(f"coverage gate needs a committed "
                             f"{baseline_path}")
    with open(baseline_path) as f:
        base = json.load(f)
    base_cov = base.get("retile_coverage", {})
    problems, retiled = [], []
    for case in harness.cases():
        k = port.compile_file(os.path.join(CORPUS, case.file),
                              name=case.kernel)
        res = k.retile(target)
        if res.factor > 1 and res.retiled:
            retiled.append(case.kernel)
        brow = base.get("kernels", {}).get(case.kernel, {}) \
            .get("targets", {}).get(target)
        if brow is None:
            continue
        if brow["retile_factor"] > 1 and res.factor <= 1:
            problems.append(
                f"{case.kernel}: re-tiled {brow['retile_factor']}x at "
                f"baseline, now narrow "
                f"({[v['reason'] for v in res.vetoes]})")
        if res.narrow_fallbacks > brow.get("narrow_fallbacks", 0):
            problems.append(
                f"{case.kernel}: narrow fallbacks grew "
                f"{brow.get('narrow_fallbacks', 0)} -> "
                f"{res.narrow_fallbacks} "
                f"({[v['reason'] for v in res.vetoes]})")
    floor = base_cov.get("retiled_kernels", 0)
    if len(retiled) < floor:
        problems.append(f"re-tile coverage dropped: {len(retiled)} "
                        f"kernels < committed {floor}")
    if problems:
        raise AssertionError("re-tile coverage regression vs committed "
                             f"{baseline_path}:\n  "
                             + "\n  ".join(problems))
    print(f"# re-tile coverage gate ({target}): {len(retiled)} kernels "
          f"re-tiled (committed floor {floor}) — OK")


def main(json_path="BENCH_port.json", differential=True,
         regression=False):
    if differential:
        print("# corpus differential check (ported vs NumPy reference)")
        count, instrs = harness.run_differential(target="rvv-128")
        print(f"#  {count} kernels match ({instrs} dynamic instrs "
              f"counted)\n")
    reports = sweep_corpus()
    print("# wall clock: interpreter vs compiled vs compiled+revec "
          f"(n={WALL_N})")
    wall = bench_wall()
    for name, row in sorted(wall.items()):
        print(f"{name:34s} {row['interp_ms']:>9.1f}ms "
              f"{row['compiled_ms']:>8.3f}ms ({row['compiled_speedup']:>7.0f}x) "
              f"{row['revec_ms']:>8.3f}ms ({row['revec_speedup']:>7.0f}x)")
    instr_ratios = check_wall_instrs(reports)
    check(reports, wall)
    print("\n# NEON corpus migration sweep "
          "(baseline / cost-driven / re-vectorized dynamic instrs)")
    print(f"{'kernel':32s}", *(f"{t.replace('rvv-', 'v'):>14s}"
                               for t in SWEEP))
    for name, rep in sorted(reports.items()):
        cells = []
        for t in SWEEP:
            row = rep["targets"][t]
            cells.append(f"{row['baseline_total_instrs']}/"
                         f"{row['total_instrs']}/"
                         f"{row['revec']['total_instrs']}")
        print(f"{name:32s}", *(f"{c:>14s}" for c in cells))
    # build the JSON payload first so the regression gate can compare
    # it against the committed file before overwriting
    tmp = emit_json(reports, wall, instr_ratios,
                    path=json_path + ".tmp")
    with open(tmp) as f:
        data = json.load(f)
    if regression:
        check_regression(data, baseline_path=json_path)
    os.replace(tmp, json_path)
    print(f"\n# wrote {json_path}")
    return reports


if __name__ == "__main__":
    if "--coverage-gate" in sys.argv[1:]:
        coverage_gate()
    else:
        main(regression="--check" in sys.argv[1:])
