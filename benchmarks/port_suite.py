"""NEON-corpus migration sweep: every ported kernel's estimated dynamic
vector-instruction count across the RVV width family, baseline (the
original-SIMDe ``vector`` policy cap) vs cost-driven selection.

This is the port-frontend analogue of benchmarks/xnnpack_suite.py: the
xnnpack suite measures the repo's *hand-written* kernels; this suite
measures *migrated legacy source* end to end (C NEON in, selections
out), which is the paper's actual task.  The sweep includes ``rvv-64``
(where Table 2's 'x' entries force Q-register intrinsics onto the
scalar loop) and ``rvv-64-m2`` (LMUL=2 register grouping making the
same intrinsics mappable again — the grouped register holds 128 bits).

  PYTHONPATH=src python benchmarks/port_suite.py        # writes BENCH_port.json
"""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CORPUS = os.path.join(ROOT, "examples", "neon_corpus")
sys.path.insert(0, CORPUS)

import harness  # noqa: E402  (the corpus differential harness)

from repro import port  # noqa: E402

# PORT_SWEEP plus the LMUL=2 grouping column
SWEEP = ("rvv-64", "rvv-64-m2", "rvv-128", "rvv-256", "rvv-512",
         "rvv-1024")

# the paper's customized-conversion showcases (Listings 5/6/7): the
# cost-driven selection must beat the original-SIMDe ladder baseline
LISTING_KERNELS = ("fold_halves_f32", "relu_bsl_f32", "bitreverse_u8")
# simple arithmetic keeps the vector tier — no win to be had (Listing 8)
ARITH_KERNELS = ("xnn_f32_vadd_ukernel", "xnn_f32_vmul_ukernel")


def sweep_corpus(n=64, seed=0):
    """port.report for every corpus kernel; returns {kernel: report}."""
    import numpy as np
    out = {}
    for i, case in enumerate(harness.cases(n=n)):
        k = port.compile_file(os.path.join(CORPUS, case.file),
                              name=case.kernel)
        rng = np.random.default_rng(seed + i)
        args = case.make_args(rng)
        out[case.kernel] = port.report(k, *args, sweep=SWEEP)
    return out


def check(reports):
    """Acceptance properties of the migration sweep."""
    assert len(reports) >= 10, f"corpus shrank to {len(reports)} kernels"
    for name in LISTING_KERNELS:
        rep = reports[name]["targets"]["rvv-128"]
        assert rep["speedup"] > 1.0, \
            f"{name}: customized conversion not cheaper ({rep['speedup']}x)"
    for name in ARITH_KERNELS:
        rep = reports[name]["targets"]["rvv-128"]
        assert abs(rep["speedup"] - 1.0) < 1e-9, \
            f"{name}: simple arithmetic should keep the vector tier"
    # Table-2 'x' entries: on rvv-64 every Q-register intrinsic falls
    # back; LMUL=2 grouping restores the native mapping
    vadd = reports["xnn_f32_vadd_ukernel"]
    assert not vadd["targets"]["rvv-64"]["maps"]["vaddq_f32"]
    assert vadd["targets"]["rvv-64-m2"]["maps"]["vaddq_f32"]
    assert vadd["targets"]["rvv-64"]["total_instrs"] > \
        vadd["targets"]["rvv-128"]["total_instrs"]


def emit_json(reports, path="BENCH_port.json"):
    data = {"suite": "neon_port_corpus",
            "metric": "dynamic_vector_instructions",
            "sweep": list(SWEEP),
            "kernels": {}}
    for name, rep in sorted(reports.items()):
        data["kernels"][name] = {
            "intrinsics": {
                i: {"sites": m["sites"], "isa_op": m["isa_op"],
                    "width_bits": m["width_bits"]}
                for i, m in sorted(rep["intrinsics"].items())},
            "targets": {
                t: {"total_instrs": row["total_instrs"],
                    "baseline_instrs": row["baseline_total_instrs"],
                    "scalar_instrs": row["scalar_instrs"],
                    "speedup": row["speedup"],
                    "unmapped": sorted(i for i, ok in row["maps"].items()
                                       if not ok)}
                for t, row in rep["targets"].items()},
        }
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    return path


def main(json_path="BENCH_port.json", differential=True):
    if differential:
        print("# corpus differential check (ported vs NumPy reference)")
        count, instrs = harness.run_differential(target="rvv-128")
        print(f"#  {count} kernels match ({instrs} dynamic instrs "
              f"counted)\n")
    reports = sweep_corpus()
    check(reports)
    print("# NEON corpus migration sweep "
          "(baseline ladder / cost-driven, dynamic vector instrs)")
    print(f"{'kernel':32s}", *(f"{t.replace('rvv-', 'v'):>12s}"
                               for t in SWEEP))
    for name, rep in sorted(reports.items()):
        cells = []
        for t in SWEEP:
            row = rep["targets"][t]
            cells.append(f"{row['baseline_total_instrs']:>5d}/"
                         f"{row['total_instrs']:<5d}")
        print(f"{name:32s}", *(f"{c:>12s}" for c in cells))
    path = emit_json(reports, json_path)
    print(f"\n# wrote {path}")
    return reports


if __name__ == "__main__":
    main()
