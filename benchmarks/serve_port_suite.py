"""Serving-tier benchmark: batched ported kernels vs one-at-a-time.

The serving engine (:mod:`repro.serve.port_engine`) answers slates of
small independent kernel requests as one jitted ``vmap`` per
(kernel, target, shape-bucket) — this suite measures what that buys and
polices what it must not cost:

* **throughput** — requests/s and per-submit p50/p99 latency, swept over
  batch size (1 / 8 / 32) x target (rvv-128 / rvv-1024); the batch-32
  engine must clear **>= 5x** the batch-1 engine's requests/s on at
  least one RVV target per kernel (XLA launch overhead amortizes across
  the batch).
* **recompile bound** — a bucket-policy sweep (``fine`` base 64 growth 2
  vs ``coarse`` growth 4) over a mixed length distribution; each
  engine's ``batch_programs`` (distinct XLA executables demanded) must
  stay within the analytic buckets x targets x kernels bound, and the
  process-wide CompiledKernel LRU must miss at most once per
  (kernel, target).

  PYTHONPATH=src python benchmarks/serve_port_suite.py           # writes BENCH_serve_port.json
  PYTHONPATH=src python benchmarks/serve_port_suite.py --check   # + regression gate
  PYTHONPATH=src python benchmarks/serve_port_suite.py --check --quick   # CI subset (no rewrite)
"""
from __future__ import annotations

import json
import math
import os
import sys
import time

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CORPUS = os.path.join(ROOT, "examples", "neon_corpus")
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro import port  # noqa: E402
from repro.serve import BucketPolicy, PortEngine, Request  # noqa: E402

# serving-shaped corpus kernels: elementwise, reduction, widening MACC
KERNELS = {
    "xnn_f32_vadd_ukernel": "vadd.c",
    "xnn_f32_vdot_ukernel": "vdot.c",
    "qs8_vmlal_dot_ukernel": "vmlal_dot.c",
}
TARGETS = ("rvv-128", "rvv-1024")
BATCHES = (1, 8, 32)
POLICIES = ("fine", "coarse")

# request-length distributions: SHORT stays inside the first bucket for
# both policies; MIXED spans two buckets (fine: 64+128, coarse: 64+256)
SHORT_N = (20, 61)
LONG_N = (70, 121)

REPEATS = 60
SPEEDUP_FLOOR = 5.0        # batch-32 vs batch-1 requests/s, best target
GATE_SLACK = 0.25          # committed-baseline floor multiplier (CI varies)


def _load_kernels(names):
    return {name: port.compile_file(os.path.join(CORPUS, fname), name=name)
            for name, fname in KERNELS.items() if name in names}


def _make_requests(kernel, count, n_range, rng, target=None):
    reqs = []
    for _ in range(count):
        n = int(rng.integers(*n_range))
        if kernel.name == "qs8_vmlal_dot_ukernel":
            a = rng.integers(-2, 3, n).astype(np.int8)
            b = rng.integers(-2, 3, n).astype(np.int8)
            out = np.zeros(1, np.int16)
        elif kernel.name == "xnn_f32_vdot_ukernel":
            a = rng.standard_normal(n).astype(np.float32)
            b = rng.standard_normal(n).astype(np.float32)
            out = np.zeros(1, np.float32)
        else:
            a = rng.standard_normal(n).astype(np.float32)
            b = rng.standard_normal(n).astype(np.float32)
            out = np.zeros(n, np.float32)
        reqs.append(Request(kernel, (n, a, b, out), target=target))
    return reqs


def _time_submits(engine, reqs, repeats=REPEATS):
    engine.submit(reqs)                      # compile + warmup
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        engine.submit(reqs)
        times.append(time.perf_counter() - t0)
    lat = np.asarray(times) * 1e3
    p50 = float(np.percentile(lat, 50))
    return {
        "batch": len(reqs),
        "reqs_per_s": round(len(reqs) / (p50 / 1e3), 1),
        "p50_ms": round(p50, 4),
        "p99_ms": round(float(np.percentile(lat, 99)), 4),
    }


def bench_batch_sweep(kernels, targets=TARGETS, batches=BATCHES, seed=0):
    """requests/s and latency per (kernel, target, batch) — all under
    the ``fine`` policy, single-bucket lengths, so the sweep isolates
    batching from bucketing."""
    rows = {}
    for kname, kernel in kernels.items():
        for tgt in targets:
            for B in batches:
                rng = np.random.default_rng(seed)
                eng = PortEngine(target=tgt, max_batch=B,
                                 bucket_policy="fine")
                reqs = _make_requests(kernel, B, SHORT_N, rng)
                rows[f"{kname}|{tgt}|b{B}"] = _time_submits(eng, reqs)
    return rows


def batch_speedups(rows, kernels, targets=TARGETS):
    """Best-target batch-32 over batch-1 requests/s per kernel."""
    out = {}
    for kname in kernels:
        per_tgt = {}
        for tgt in targets:
            lo = rows.get(f"{kname}|{tgt}|b1")
            hi = rows.get(f"{kname}|{tgt}|b{max(BATCHES)}")
            if lo and hi:
                per_tgt[tgt] = round(hi["reqs_per_s"] / lo["reqs_per_s"], 2)
        if per_tgt:
            out[kname] = per_tgt
    return out


def bench_policy_sweep(kernels, targets=TARGETS, policies=POLICIES,
                       seed=1, batch=32):
    """Mixed-length traffic through each bucket policy: measures padding
    overhead and proves the executable count stays within the analytic
    buckets x targets x kernels bound."""
    out = {}
    for pol in policies:
        policy = BucketPolicy.preset(pol)
        before = port.compiled_cache_info()
        eng = PortEngine(max_batch=batch, bucket_policy=pol)
        rng = np.random.default_rng(seed)
        expected_sigs = set()
        lat = []
        for kname, kernel in kernels.items():
            for tgt in targets:
                # half short, half long: two buckets per policy
                reqs = (_make_requests(kernel, batch // 2, SHORT_N, rng,
                                       target=tgt)
                        + _make_requests(kernel, batch - batch // 2,
                                         LONG_N, rng, target=tgt))
                for r in reqs:
                    expected_sigs.add((kname, tgt,
                                       policy.bucket(int(r.args[0]))))
                eng.submit(reqs)             # compile + warmup
                t0 = time.perf_counter()
                eng.submit(reqs)
                lat.append(time.perf_counter() - t0)
        st = eng.stats()
        after = st["compile_cache"]
        bound = len(expected_sigs)
        assert st["batch_programs"] <= bound, \
            f"{pol}: {st['batch_programs']} XLA programs exceed the " \
            f"buckets x targets x kernels bound {bound}"
        new_misses = after["misses"] - before["misses"]
        assert new_misses <= len(kernels) * len(targets), \
            f"{pol}: {new_misses} compile-cache misses for " \
            f"{len(kernels)} kernels x {len(targets)} targets"
        out[pol] = {
            "batch_programs": st["batch_programs"],
            "program_bound": bound,
            "buckets": sorted({b for _, _, b in expected_sigs}),
            "pad_overhead": round(st["pad_overhead"], 3),
            "inert_rows": st["inert_rows"],
            "compile_cache_misses": new_misses,
            "submit_p50_ms": round(float(np.median(lat)) * 1e3, 3),
        }
    return out


def check(rows, speedups):
    """Acceptance: batched serving must beat single-request serving by
    >= SPEEDUP_FLOOR on at least one RVV target per kernel."""
    assert speedups, "no batch-sweep rows to check"
    for kname, per_tgt in speedups.items():
        best = max(per_tgt.values())
        assert best >= SPEEDUP_FLOOR, \
            f"{kname}: batch-{max(BATCHES)} only {best}x batch-1 " \
            f"requests/s (want >= {SPEEDUP_FLOOR}x); {per_tgt}"
    for key, row in rows.items():
        assert row["p99_ms"] > 0 and row["reqs_per_s"] > 0, (key, row)


def emit_json(rows, speedups, engines, path="BENCH_serve_port.json"):
    data = {
        "suite": "serve_port",
        "metric": "requests_per_second",
        "targets": list(TARGETS),
        "batch_sizes": list(BATCHES),
        "policies": list(POLICIES),
        "speedup_floor": SPEEDUP_FLOOR,
        "rows": {k: rows[k] for k in sorted(rows)},
        "batch_speedup": speedups,
        "engines": engines,
        "compile_cache": port.compiled_cache_info(),
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    return path


def check_regression(data, baseline_path="BENCH_serve_port.json",
                     slack=GATE_SLACK):
    """Fresh requests/s may not collapse below ``slack`` x the committed
    baseline (absolute floors stay with :func:`check`; this guards
    relative rot on rows both runs measured)."""
    if not os.path.exists(baseline_path):
        print(f"# no committed {baseline_path}; skipping regression gate")
        return
    with open(baseline_path) as f:
        base = json.load(f)
    problems = []
    for key, row in data["rows"].items():
        brow = base.get("rows", {}).get(key)
        if brow is None:
            continue
        floor = brow["reqs_per_s"] * slack
        if row["reqs_per_s"] < floor:
            problems.append(
                f"{key}: {row['reqs_per_s']:.0f} req/s below floor "
                f"{floor:.0f} (baseline {brow['reqs_per_s']:.0f})")
    for pol, eng in data.get("engines", {}).items():
        beng = base.get("engines", {}).get(pol)
        if beng and eng["batch_programs"] > beng["program_bound"]:
            problems.append(
                f"{pol}: batch_programs {eng['batch_programs']} > "
                f"baseline bound {beng['program_bound']}")
    if problems:
        raise AssertionError("BENCH_serve_port regression vs committed "
                             "baseline:\n  " + "\n  ".join(problems))
    print(f"# regression gate vs {baseline_path}: OK")


def main(json_path="BENCH_serve_port.json", regression=False,
         quick=False):
    global TARGETS, BATCHES, POLICIES
    if quick:
        # CI subset: one target, endpoint batch sizes, one policy —
        # still exercises every assertion
        TARGETS = ("rvv-128",)
        BATCHES = (1, 32)
        POLICIES = ("fine",)
        names = ("xnn_f32_vadd_ukernel", "qs8_vmlal_dot_ukernel")
    else:
        names = tuple(KERNELS)
    kernels = _load_kernels(names)

    print(f"# batch sweep: requests/s, p50/p99 per submit "
          f"(batches {BATCHES}, targets {TARGETS})")
    rows = bench_batch_sweep(kernels, targets=TARGETS, batches=BATCHES)
    for key in sorted(rows):
        r = rows[key]
        print(f"{key:44s} {r['reqs_per_s']:>10.0f} req/s  "
              f"p50 {r['p50_ms']:>7.3f}ms  p99 {r['p99_ms']:>7.3f}ms")
    speedups = batch_speedups(rows, kernels, targets=TARGETS)
    print("\n# batch-32 vs batch-1 requests/s (per kernel, per target)")
    for kname, per_tgt in sorted(speedups.items()):
        print(f"{kname:34s} "
              + "  ".join(f"{t}: {s:>5.1f}x" for t, s in per_tgt.items()))

    print(f"\n# bucket-policy sweep: mixed lengths "
          f"{SHORT_N}+{LONG_N}, policies {POLICIES}")
    engines = bench_policy_sweep(kernels, targets=TARGETS,
                                 policies=POLICIES)
    for pol, eng in engines.items():
        print(f"{pol:8s} programs {eng['batch_programs']}/"
              f"{eng['program_bound']} (buckets {eng['buckets']})  "
              f"pad {eng['pad_overhead']:.0%}  "
              f"cache misses {eng['compile_cache_misses']}")
    check(rows, speedups)

    if quick:
        # subset run: gate against the committed baseline, never
        # overwrite it
        if regression:
            data = {"rows": rows, "batch_speedup": speedups,
                    "engines": engines}
            check_regression(data, baseline_path=json_path)
        print("\n# quick mode: baseline not rewritten")
        return rows
    tmp = emit_json(rows, speedups, engines, path=json_path + ".tmp")
    with open(tmp) as f:
        data = json.load(f)
    if regression:
        check_regression(data, baseline_path=json_path)
    os.replace(tmp, json_path)
    print(f"\n# wrote {json_path}")
    return rows


if __name__ == "__main__":
    main(regression="--check" in sys.argv[1:],
         quick="--quick" in sys.argv[1:])
