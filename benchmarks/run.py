"""Benchmark harness — one entry per paper table/figure + system benches.

  PYTHONPATH=src python -m benchmarks.run            # everything
Prints ``name,us_per_call,derived`` CSV rows per the harness contract.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp


def _bench(fn, *args, n=5, warmup=1, **kw):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / n * 1e6


def bench_xnnpack():
    """Paper Figure 2: customized vs baseline, both cost models."""
    from benchmarks import xnnpack_suite
    out = xnnpack_suite.main()
    rows = []
    for r in out["rvv128"]:
        rows.append((f"xnnpack/{r['name']}", 0.0,
                     f"speedup={r['speedup']}x"))
    return rows


def bench_type_table():
    """Paper Table 2: NEON type mapping on the TPU target."""
    from repro.core import neon_type_table
    table = neon_type_table()
    n_valid = sum(tm.valid for tm in table.values())
    print(f"# Table 2: {n_valid}/{len(table)} NEON types map "
          f"(waste = padding lanes at register granularity)")
    return [("type_table/valid", 0.0, f"{n_valid}/{len(table)}")]


def bench_train_step():
    """End-to-end reduced-config train step wall time (CPU)."""
    from repro.configs import get_config
    from repro.models import model as M
    from repro.optim import adamw
    from repro.train.loop import TrainConfig, make_train_step
    from repro.data.pipeline import SyntheticLM
    rows = []
    for arch in ("gemma2-2b", "mamba2-1.3b", "granite-moe-1b-a400m"):
        cfg = get_config(arch).reduced()
        params = M.init(cfg, jax.random.PRNGKey(0))
        opt = adamw.init(params)
        batch = SyntheticLM(cfg.vocab_size, 64, 4).batch(0)
        step = jax.jit(make_train_step(cfg, TrainConfig()))
        us = _bench(lambda: step(params, opt, None, batch)[3]["loss"], n=3)
        rows.append((f"train_step/{arch}", round(us, 1), "reduced-config"))
    return rows


def bench_decode_step():
    """Serving decode step wall time (CPU, reduced)."""
    from repro.configs import get_config
    from repro.models import model as M
    from repro.serve.engine import Engine
    rows = []
    for arch in ("gemma2-2b", "mamba2-1.3b"):
        cfg = get_config(arch).reduced()
        params = M.init(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, max_batch=4, max_seq=64)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 2,
                                     cfg.vocab_size)
        eng.prefill(prompts)
        tok = jnp.zeros((4,), jnp.int32)
        us = _bench(lambda: eng.decode(tok, 1), n=3)
        rows.append((f"decode_step/{arch}", round(us, 1), "bs=4"))
    return rows


def bench_roofline():
    """§Roofline table from the dry-run artifact (if present)."""
    path = "results/dryrun_opt.json"
    if not os.path.exists(path):
        path = "results/dryrun.json"
    if not os.path.exists(path):
        print("# roofline: results/dryrun.json missing — run "
              "`python -m repro.launch.dryrun --all --mesh single --out "
              "results/dryrun.json` first")
        return []
    from benchmarks import roofline
    rows = roofline.report(path)
    print(roofline.fmt_table(rows))
    ok = [r for r in rows if r["status"] == "ok"]
    return [(f"roofline/{r['arch']}/{r['shape']}", 0.0,
             f"dom={r['dominant']},frac={r['roofline_fraction']:.3f}")
            for r in ok]


def main() -> None:
    all_rows = []
    for fn in (bench_type_table, bench_xnnpack, bench_train_step,
               bench_decode_step, bench_roofline):
        try:
            all_rows += fn()
        except Exception as e:  # noqa: BLE001
            print(f"# {fn.__name__} failed: {e!r}")
    print("\nname,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us},{derived}")


if __name__ == '__main__':
    main()
