"""Retired-instruction suite for the emitted-RVV path.

Where benchmarks/port_suite.py sweeps the *cost model* (estimated
dynamic instructions), this suite executes the **emitted RVV intrinsic
streams** on the in-repo simulator (``repro.rvv``) and records what
actually retired — vector instructions, explicit and compiler-implicit
``vsetvli``s, and LMUL-weighted vuops — per corpus kernel per width.
Every run is also a differential check: the simulator's outputs must
match the exact NumPy references before a count is recorded.

Acceptance mirrors the re-vectorizer's bar, now on retired facts
instead of estimates: scalable strip kernels must retire >= 4x fewer
instructions on rvv-1024 than on rvv-128 at serving size, and the
fixed-shape counter-examples must not budge.

When an RVV-capable C compiler is on PATH (clang with a riscv64
target, or a riscv64 cross gcc), every emitted unit is additionally
syntax-checked under ``-march=rv64gcv``; otherwise that smoke is
skipped and reported as such.

  PYTHONPATH=src python benchmarks/rvv_sim_suite.py          # writes BENCH_rvv_sim.json
  PYTHONPATH=src python benchmarks/rvv_sim_suite.py --check  # + regression gate
                                                             #   vs committed JSON
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import tempfile

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CORPUS = os.path.join(ROOT, "examples", "neon_corpus")
sys.path.insert(0, CORPUS)

import harness  # noqa: E402  (the corpus differential harness)

from repro import port, rvv  # noqa: E402

SWEEP = ("rvv-64", "rvv-128", "rvv-256", "rvv-512", "rvv-1024")

# serving-realistic geometry: enough strips that per-loop constants
# amortize and the width family separates
BENCH_N, BENCH_TAIL_N = 1024, 1027

# fixed-shape counter-example: fold's cross-lane vget_high/low
# structure stays at NEON granularity, so its retired count must NOT
# scale with VLEN.  (The qs8 gemm used to sit here; per-site offset
# re-tiling now widens its inner dot strip, so it must scale.)
UNSCALABLE = ("fold_halves_f32",)


def sweep_corpus(seed=0):
    """Emit + simulate every corpus kernel across the width family.

    Returns ``{kernel: {target: counts}}`` where counts are the
    simulator's retired tallies; raises if any simulated output
    diverges from the exact NumPy reference."""
    import numpy as np
    out = {}
    for i, case in enumerate(harness.cases(n=BENCH_N,
                                           tail_n=BENCH_TAIL_N)):
        k = port.compile_file(os.path.join(CORPUS, case.file),
                              name=case.kernel)
        rng = np.random.default_rng(seed + i)
        args = case.make_args(rng)
        want = case.reference(*args)
        rows = {}
        for target in SWEEP:
            got, counts = rvv.execute(rvv.emit(k, target), *args)
            _assert_close(got, want, case, target)
            rows[target] = {
                "executed": counts["executed"],
                "vector": counts["vector"],
                "vsetvli": (counts["vsetvli"]
                            + counts["implicit_vsetvli"]),
                "vuops": counts["vuops"],
            }
        out[case.kernel] = rows
    return out


def _assert_close(got, want, case, target):
    import numpy as np
    got = got if isinstance(got, tuple) else (got,)
    want = want if isinstance(want, tuple) else (want,)
    for g, w in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g, dtype=np.float64),
            np.asarray(w, dtype=np.float64),
            rtol=max(case.rtol, 1e-5), atol=max(case.atol, 1e-6),
            err_msg=f"{case.kernel} on {target}: simulated RVV "
                    f"diverged from the reference")


def check(counts):
    """Acceptance on retired facts."""
    assert len(counts) >= 20, f"corpus shrank to {len(counts)} kernels"
    ratios = {}
    for name, rows in counts.items():
        r = rows["rvv-128"]["executed"] / max(1,
                                              rows["rvv-1024"]["executed"])
        ratios[name] = round(r, 2)
        if name in UNSCALABLE:
            assert r <= 1.5, \
                f"{name}: fixed-shape kernel's retired count moved " \
                f"with VLEN ({r:.2f}x)"
        else:
            assert r >= 4.0, \
                f"{name}: rvv-1024 retired only {r:.2f}x fewer " \
                f"instructions than rvv-128 (want >= 4x)"
        # wider registers never cost more retired work anywhere in the
        # family (monotone down the sweep)
        seq = [rows[t]["executed"] for t in SWEEP]
        assert all(a >= b for a, b in zip(seq, seq[1:])), \
            f"{name}: retired counts not monotone across {SWEEP}: {seq}"
    return ratios


def syntax_smoke():
    """-fsyntax-only every emitted unit when an RVV compiler exists.

    Returns ``(compiler, n_units)`` or ``(None, 0)`` when no toolchain
    on PATH accepts ``-march=rv64gcv`` (the common case in CI)."""
    cc = _find_rvv_cc()
    if cc is None:
        msg = ("rv64gcv syntax smoke SKIPPED: no RVV-capable compiler "
               "on PATH (probed clang --target=riscv64, "
               "riscv64-linux-gnu-gcc, riscv64-unknown-elf-gcc)")
        # an explicit annotation, not a silent pass: CI surfaces the
        # skip in the run summary so nobody mistakes it for coverage
        if os.environ.get("GITHUB_ACTIONS"):
            print(f"::notice title=rvv_sim_suite::{msg}")
        print(f"# {msg}")
        return None, 0
    n = 0
    with tempfile.TemporaryDirectory() as td:
        for case in harness.cases():
            k = port.compile_file(os.path.join(CORPUS, case.file),
                                  name=case.kernel)
            for target in SWEEP:
                path = os.path.join(td, f"{case.kernel}_{n}.c")
                with open(path, "w") as f:
                    f.write(rvv.emit(k, target).render_c())
                subprocess.run(cc + ["-fsyntax-only", path], check=True)
                n += 1
    print(f"# rv64gcv syntax smoke: {n} units clean under "
          f"{' '.join(cc)}")
    return cc, n


def _find_rvv_cc():
    probes = [["clang", "--target=riscv64", "-march=rv64gcv"],
              ["riscv64-linux-gnu-gcc", "-march=rv64gcv"],
              ["riscv64-unknown-elf-gcc", "-march=rv64gcv"]]
    for cc in probes:
        if shutil.which(cc[0]) is None:
            continue
        with tempfile.NamedTemporaryFile("w", suffix=".c") as f:
            f.write("#include <riscv_vector.h>\nint main(void)"
                    "{return 0;}\n")
            f.flush()
            r = subprocess.run(cc + ["-fsyntax-only", f.name],
                               capture_output=True)
        if r.returncode == 0:
            return cc
    return None


def emit_json(counts, ratios, path="BENCH_rvv_sim.json"):
    data = {"suite": "rvv_sim_corpus",
            "metric": "retired_instructions",
            "sweep": list(SWEEP),
            "n": BENCH_N,
            "kernels": {}}
    for name, rows in sorted(counts.items()):
        data["kernels"][name] = {
            "targets": {t: dict(rows[t]) for t in SWEEP},
            "ratio_rvv128_over_rvv1024": ratios[name],
        }
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return data


def check_regression(data, baseline_path="BENCH_rvv_sim.json"):
    """Retired counts may not grow against the committed baseline —
    every codegen change that adds instructions is a reviewed diff."""
    if not os.path.exists(baseline_path):
        print(f"# no committed {baseline_path}; skipping regression "
              "gate")
        return
    with open(baseline_path) as f:
        base = json.load(f)
    problems = []
    for name, krow in base.get("kernels", {}).items():
        fresh = data["kernels"].get(name)
        if fresh is None:
            problems.append(f"{name}: kernel disappeared from the "
                            "corpus")
            continue
        for t, row in krow.get("targets", {}).items():
            frow = fresh["targets"].get(t)
            if frow is None:
                continue
            for key in ("executed", "vuops"):
                if frow[key] > row[key]:
                    problems.append(
                        f"{name}/{t}: {key} {row[key]} -> {frow[key]}")
    if problems:
        raise AssertionError("BENCH_rvv_sim regression vs committed "
                             "baseline:\n  " + "\n  ".join(problems))
    print(f"# regression gate vs {baseline_path}: OK")


def main(json_path="BENCH_rvv_sim.json", regression=False):
    print(f"# emitted-RVV retired-instruction sweep "
          f"(n={BENCH_N}, differential vs NumPy references)")
    counts = sweep_corpus()
    ratios = check(counts)
    print(f"#  {len(counts)} kernels match across {len(SWEEP)} widths")
    scal = {k: v for k, v in ratios.items() if k not in UNSCALABLE}
    lo, hi = min(scal, key=scal.get), max(scal, key=scal.get)
    print(f"#  rvv-128/rvv-1024 retired ratio: {scal[lo]:.2f}x ({lo}) "
          f"to {scal[hi]:.2f}x ({hi})")
    syntax_smoke()
    if regression:
        # gate BEFORE overwriting the committed baseline
        data = {"kernels": {
            name: {"targets": {t: dict(rows[t]) for t in SWEEP},
                   "ratio_rvv128_over_rvv1024": ratios[name]}
            for name, rows in counts.items()}}
        check_regression(data, baseline_path=json_path)
    emit_json(counts, ratios, path=json_path)
    print(f"# wrote {json_path}")


if __name__ == "__main__":
    main(regression="--check" in sys.argv[1:])
