"""Resilience benchmark: what a fault costs, and what it must never cost.

The degradation ladder (:mod:`repro.port.resilience`) promises that a
fault at any pipeline seam only trades *speed*, never *values*.  This
suite injects each fault class (:mod:`repro.port.faultinject`) into
real ladder runs and measures

* **fallback rate** — fraction of faulted runs that served from a lower
  rung, which must exactly match the class's expected rate (a veto or a
  persistent compile failure always degrades; a transient timeout, an
  eviction storm, or a corrupted cache entry never does), and
* **recovery latency** — wall time of the faulted ladder run vs the
  fault-free baseline, per class (informational: how much the fallback
  rung costs).

The ``--check`` gate enforces the structural invariants: **zero silent
corruption** (every faulted output bitwise-equal to the fault-free run
of the rung that served it), expected-rung match rate 1.0, and every
degraded run leaving a DegradationRecord.

  PYTHONPATH=src python benchmarks/resilience_suite.py           # writes BENCH_resilience.json
  PYTHONPATH=src python benchmarks/resilience_suite.py --check   # + invariant gate
  PYTHONPATH=src python benchmarks/resilience_suite.py --check --quick   # CI subset (no rewrite)
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CORPUS = os.path.join(ROOT, "examples", "neon_corpus")
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro import port  # noqa: E402
from repro.port import faultinject as fi  # noqa: E402
from repro.port import resilience as rz  # noqa: E402

KERNELS = {
    "xnn_f32_vadd_ukernel": "vadd.c",       # elementwise strip
    "xnn_f32_vdot_ukernel": "vdot.c",       # reduction
    "qs8_vmlal_dot_ukernel": "vmlal_dot.c",  # widening MACC
}
TARGETS = ("rvv-128", "rvv-1024")
N = 61
REPEATS = 3

# fault class -> (seam, error, times, expected rung, expected degraded)
FAULT_CLASSES = {
    "revec_veto": ("revec.retile", "RevecVeto", None, "compiled", True),
    "compile_fail": ("compile.trace", "CompileError", None, "interp",
                     True),
    "runtime_fault": ("compile.run", "ExecError", None, "interp", True),
    "transient_timeout": ("compile.trace", "CompileTimeout", 1,
                          "compiled+revec", False),
    "eviction_storm": (None, None, None, "compiled+revec", False),
    "corrupted_cache": (None, None, None, "compiled+revec", False),
}


def _load_kernels(names):
    return {name: port.compile_file(os.path.join(CORPUS, fname),
                                    name=name)
            for name, fname in KERNELS.items() if name in names}


def _args_for(kernel, rng):
    n = N
    if kernel.name == "qs8_vmlal_dot_ukernel":
        return (n, rng.integers(-2, 3, n).astype(np.int8),
                rng.integers(-2, 3, n).astype(np.int8),
                np.zeros(1, np.int16))
    out_len = 1 if kernel.name == "xnn_f32_vdot_ukernel" else n
    return (n, rng.standard_normal(n).astype(np.float32),
            rng.standard_normal(n).astype(np.float32),
            np.zeros(out_len, np.float32))


def _bitwise_equal(got, want):
    got = got if isinstance(got, tuple) else (got,)
    want = want if isinstance(want, tuple) else (want,)
    return len(got) == len(want) and all(
        np.array_equal(np.asarray(g), np.asarray(w))
        for g, w in zip(got, want))


def _timed_ladder(kernel, args, target, repeats=REPEATS):
    best, out, rec = None, None, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out, rec = rz.run_resilient(kernel, *args, target=target,
                                    jit=False)
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return out, rec, best * 1e3


def _run_class(cls, kernel, args, target, refs):
    """One faulted ladder run per class; first-recovery latency is the
    interesting number, so the cache is cleared before injection for
    compile-seam classes."""
    seam, err_name, times, want_rung, want_degraded = FAULT_CLASSES[cls]
    rz.reset_resilience()
    if cls == "eviction_storm":
        with fi.eviction_storm(1):
            out, rec, ms = _timed_ladder(kernel, args, target)
    elif cls == "corrupted_cache":
        port.compiled_cache_clear()
        kernel.compile(target=target, revec=True, jit=False)
        fi.corrupt_cache_entry(kernel.fn.name)
        t0 = time.perf_counter()
        out, rec = rz.run_resilient(kernel, *args, target=target,
                                    jit=False)
        ms = (time.perf_counter() - t0) * 1e3
    else:
        port.compiled_cache_clear()
        with fi.injected(seam, error=getattr(rz, err_name),
                         times=times):
            t0 = time.perf_counter()
            out, rec = rz.run_resilient(kernel, *args, target=target,
                                        jit=False)
            ms = (time.perf_counter() - t0) * 1e3
    corrupt = not _bitwise_equal(out, refs[rec.used])
    recorded = (not rec.degraded) or bool(
        rz.degradation_records(kernel=kernel.fn.name))
    return {
        "used": rec.used,
        "degraded": rec.degraded,
        "rung_ok": rec.used == want_rung,
        "degraded_ok": rec.degraded == want_degraded,
        "corrupt": corrupt,
        "recorded": recorded,
        "recovery_ms": round(ms, 3),
    }


def bench(kernels, targets=TARGETS, classes=None):
    classes = classes or tuple(FAULT_CLASSES)
    rows = {}
    for kname, kernel in kernels.items():
        args = _args_for(kernel, np.random.default_rng(0))
        for tgt in targets:
            port.compiled_cache_clear()
            rz.reset_resilience()
            # fault-free per-rung references + baseline latency
            out, rec, base_ms = _timed_ladder(kernel, args, tgt)
            refs = {
                "compiled+revec": out,
                "compiled": kernel.compile(target=tgt, revec=False,
                                           jit=False)(*args),
                "interp": kernel(*args, target=tgt),
            }
            for cls in classes:
                row = _run_class(cls, kernel, args, tgt, refs)
                row["baseline_ms"] = round(base_ms, 3)
                rows[f"{cls}|{kname}|{tgt}"] = row
    return rows


def aggregate(rows):
    per_class = {}
    for key, row in rows.items():
        cls = key.split("|")[0]
        agg = per_class.setdefault(cls, {
            "runs": 0, "fallbacks": 0, "corruptions": 0,
            "rung_mismatches": 0, "unrecorded": 0, "recovery_ms": []})
        agg["runs"] += 1
        agg["fallbacks"] += int(row["degraded"])
        agg["corruptions"] += int(row["corrupt"])
        agg["rung_mismatches"] += int(not (row["rung_ok"] and
                                           row["degraded_ok"]))
        agg["unrecorded"] += int(not row["recorded"])
        agg["recovery_ms"].append(row["recovery_ms"])
    out = {}
    for cls, agg in per_class.items():
        lat = np.asarray(agg["recovery_ms"])
        out[cls] = {
            "runs": agg["runs"],
            "fallback_rate": round(agg["fallbacks"] / agg["runs"], 3),
            "expected_fallback_rate": float(
                FAULT_CLASSES[cls][4]),
            "corruptions": agg["corruptions"],
            "rung_mismatches": agg["rung_mismatches"],
            "unrecorded": agg["unrecorded"],
            "recovery_p50_ms": round(float(np.median(lat)), 3),
            "recovery_max_ms": round(float(lat.max()), 3),
        }
    return out


def check(summary):
    """The resilience contract, as hard gates."""
    problems = []
    for cls, agg in summary.items():
        if agg["corruptions"]:
            problems.append(f"{cls}: {agg['corruptions']} silently "
                            f"corrupted outputs")
        if agg["rung_mismatches"]:
            problems.append(f"{cls}: {agg['rung_mismatches']} runs "
                            f"served from an unexpected rung")
        if agg["fallback_rate"] != agg["expected_fallback_rate"]:
            problems.append(
                f"{cls}: fallback rate {agg['fallback_rate']} != "
                f"expected {agg['expected_fallback_rate']}")
        if agg["unrecorded"]:
            problems.append(f"{cls}: {agg['unrecorded']} degraded runs "
                            f"left no DegradationRecord")
    if problems:
        raise AssertionError("resilience contract violated:\n  " +
                             "\n  ".join(problems))
    print("# resilience gate: zero corruption, all rungs as expected")


def emit_json(rows, summary, path="BENCH_resilience.json"):
    data = {
        "suite": "resilience",
        "metric": "fallback_rate_and_recovery_latency",
        "targets": list(TARGETS),
        "fault_classes": {
            cls: {"seam": spec[0], "error": spec[1],
                  "expected_rung": spec[3]}
            for cls, spec in FAULT_CLASSES.items()},
        "rows": {k: rows[k] for k in sorted(rows)},
        "per_class": summary,
        "ladder_stats": rz.resilience_stats(),
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    return path


def main(json_path="BENCH_resilience.json", regression=False,
         quick=False):
    global TARGETS
    if quick:
        # CI subset: one kernel x one target still runs every fault
        # class through every gate
        TARGETS = ("rvv-128",)
        names = ("xnn_f32_vadd_ukernel",)
    else:
        names = tuple(KERNELS)
    kernels = _load_kernels(names)
    fi.disarm_all()
    rz.reset_resilience()

    print(f"# fault classes {tuple(FAULT_CLASSES)} x kernels "
          f"{tuple(kernels)} x targets {TARGETS}")
    rows = bench(kernels, targets=TARGETS)
    summary = aggregate(rows)
    for cls, agg in sorted(summary.items()):
        print(f"{cls:20s} fallback {agg['fallback_rate']:>4.0%} "
              f"(want {agg['expected_fallback_rate']:.0%})  "
              f"recovery p50 {agg['recovery_p50_ms']:>9.3f}ms  "
              f"corrupt {agg['corruptions']}")
    if regression:
        check(summary)
    if quick:
        print("# quick mode: baseline not rewritten")
        return summary
    tmp = emit_json(rows, summary, path=json_path + ".tmp")
    os.replace(tmp, json_path)
    print(f"# wrote {json_path}")
    return summary


if __name__ == "__main__":
    main(regression="--check" in sys.argv[1:],
         quick="--quick" in sys.argv[1:])
