"""Roofline analysis over the dry-run JSON (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, three terms in seconds (v5e peaks):

  compute    = per-device HLO FLOPs / peak_FLOP/s
  memory     = per-device HLO HBM bytes / HBM_bw
  collective = per-device collective bytes / ici_bw

FLOPs/bytes/collective-bytes come from the trip-count-corrected HLO
analysis (launch/hlo_analysis.py) of the SPMD-partitioned module, so
they are already per-device per-step.  MODEL_FLOPS = 6·N·D (train,
N=active params) or 2·N·D (decode/prefill) gives the useful-compute
ratio, exposing remat/replication waste.

CPU-compile caveat: XLA:CPU upcasts bf16 compute to f32, so byte terms
carry a <=2x pessimism for bf16 activations vs a real TPU lowering; the
FLOP and collective terms are layout-exact.
"""
from __future__ import annotations

import argparse
import json
import math
import os

from repro.core.targets import compile_target, current_target
from repro.configs import SHAPES, get_config


def model_flops(arch: str, shape_name: str, accum_meta=None) -> float:
    """Analytic useful FLOPs per step (global, fwd+bwd for train)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    _, active = cfg.param_counts()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    # decode: one token per row
    return 2.0 * active * shape.global_batch


def memory_bytes(rec) -> float:
    """Analytic per-device HBM traffic per step (fused-quality lowering).

    The HLO-text byte count models a fully *unfused* op-by-op program
    (every instruction round-trips HBM — the SIMDe-generic semantics); a
    real TPU lowering fuses elementwise chains, so the memory term uses
    an explicit traffic model instead:

      train:   params (fwd+bwd+remat reads per microbatch) + optimizer
               read/write + grad-accum buffer + ~16 materialized
               residual-sized tensors per layer per pass + attention KV
               streaming (+ MoE buffers)
      prefill: fwd-only subset + cache write
      decode:  params once + full KV/state cache read + cache write

    The unfused HLO number is kept as ``bytes_unfused`` (upper bound).
    """
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_dev = rec.get("n_devices", 256)
    accum = rec.get("accum", 1)
    total_p, _ = cfg.param_counts()
    p_dev = total_p / n_dev
    bp = 2  # bf16 param/activation bytes

    if shape.kind == "train":
        rows_dev = max(1, shape.global_batch // (16 * accum))  # data=16
        act = rows_dev * shape.seq_len * cfg.d_model * bp
        layers = cfg.n_layers + cfg.n_enc_layers
        traffic = 0.0
        traffic += 3 * accum * p_dev * bp            # fwd+remat+bwd reads
        traffic += 30 * p_dev                         # adam fp32 rw + cast
        traffic += 2 * 4 * accum * p_dev              # grad-accum buffer rw
        traffic += 16 * act * layers * accum          # materialized acts
        # attention/ssd streaming per layer per microbatch (~3 visits)
        if cfg.attn_kind != "none":
            kv = rows_dev * shape.seq_len * max(
                cfg.n_kv_heads * cfg.head_dim, cfg.kv_lora_rank) * bp
            nq = max(1, shape.seq_len // 512)
            traffic += 3 * accum * layers * nq * 2 * kv
        if cfg.n_experts:
            cap = shape.global_batch * shape.seq_len * cfg.top_k / \
                cfg.n_experts * cfg.capacity_factor
            buf = cfg.n_experts * cap * cfg.d_model * bp / n_dev
            traffic += 4 * 3 * accum * cfg.n_layers * buf
        # logits (vocab-sharded) fwd+bwd
        from repro.models.layers import padded_vocab
        traffic += 4 * accum * rows_dev * shape.seq_len * \
            padded_vocab(cfg) / 16 * 4
        return traffic

    if shape.kind == "prefill":
        rows_dev = max(1, shape.global_batch // 16)
        act = rows_dev * shape.seq_len * cfg.d_model * bp
        layers = cfg.n_layers + cfg.n_enc_layers
        traffic = p_dev * bp + 8 * act * layers
        if cfg.attn_kind != "none":
            kv = rows_dev * shape.seq_len * max(
                cfg.n_kv_heads * cfg.head_dim, cfg.kv_lora_rank) * bp
            nq = max(1, shape.seq_len // 512)
            traffic += layers * nq * 2 * kv + 2 * layers * kv  # + cache wr
        return traffic

    # decode: one token for every row against the full cache
    rows_dev = max(1, shape.global_batch // min(16, shape.global_batch))
    traffic = p_dev * bp
    layers = cfg.n_layers
    if cfg.attn_kind != "none":
        slots = min(cfg.window, shape.seq_len) if (
            cfg.window and cfg.local_global) else shape.seq_len
        pat = cfg.layer_pattern()
        for kind in pat:
            if kind in ("mamba", "mamba_shared"):
                continue
            s_eff = min(cfg.window or shape.seq_len, shape.seq_len) \
                if kind == "local" else shape.seq_len
            kv_dim = max(cfg.n_kv_heads * cfg.head_dim, cfg.kv_lora_rank)
            traffic += 2 * rows_dev * s_eff * kv_dim * bp / \
                max(1, min(16, cfg.n_kv_heads))  # heads sharded on model
    if cfg.ssm_state:
        state = cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state * 4
        n_mamba = sum(k.startswith("mamba") for k in cfg.layer_pattern())
        traffic += 2 * rows_dev * state * n_mamba / 16
    return traffic


def terms(rec, target=None):
    target = target or current_target()
    if target.peak_flops_bf16 <= 0:
        # RVV cost models carry no machine peaks; the roofline is a
        # TPU-side report, so fall back to the compile target.
        target = compile_target()
    comp = rec["flops"] / target.peak_flops_bf16
    mem = memory_bytes(rec) / target.hbm_bw
    coll = rec["collective_total"] / target.ici_bw
    dom = max(("compute", comp), ("memory", mem), ("collective", coll),
              key=lambda kv: kv[1])
    mf = model_flops(rec["arch"], rec["shape"])
    n_dev = rec.get("n_devices", 256)
    useful = mf / max(1.0, rec["flops"] * n_dev)
    bound = max(comp, mem, coll)
    # roofline fraction: useful work at peak vs modeled step time
    ideal = mf / n_dev / target.peak_flops_bf16
    frac = ideal / bound if bound > 0 else 0.0
    return {"compute_s": comp, "memory_s": mem, "collective_s": coll,
            "dominant": dom[0], "model_flops": mf,
            "useful_flops_ratio": useful, "roofline_fraction": frac,
            "bytes_unfused": rec["bytes_accessed"]}


def suggestion(rec, t):
    d = t["dominant"]
    if d == "collective":
        return ("reduce collective volume: overlap/reschedule, shard_map "
                "local dispatch (MoE), int8 cross-pod grads")
    if d == "memory":
        return ("cut HBM round-trips: fuse epilogues, bigger microbatch, "
                "bf16-native lowering, avoid replicated activations")
    if t["useful_flops_ratio"] < 0.5:
        return ("compute is majority waste: remove replicated attention "
                "compute / cheaper remat policy")
    return "compute-bound and mostly useful: tune block shapes / MXU util"


def report(path: str, mesh: str = "pod16x16"):
    with open(path) as f:
        rows = json.load(f)
    out = []
    for rec in rows:
        if rec.get("mesh") != mesh:
            continue
        if rec["status"] == "skipped":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": mesh, "status": "skipped",
                        "reason": rec.get("reason", "")})
            continue
        if rec["status"] != "ok":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": mesh, "status": "error"})
            continue
        t = terms(rec)
        out.append({"arch": rec["arch"], "shape": rec["shape"], "mesh": mesh,
                    "status": "ok", **t, "note": suggestion(rec, t),
                    "hlo_flops_dev": rec["flops"],
                    "hlo_bytes_dev": rec["bytes_accessed"],
                    "coll_bytes_dev": rec["collective_total"]})
    return out


def fmt_table(rows):
    hdr = (f"{'arch':22s} {'shape':12s} {'comp_ms':>8s} {'mem_ms':>8s} "
           f"{'coll_ms':>8s} {'dom':>5s} {'useful':>7s} {'roofl%':>7s}")
    lines = [hdr]
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"{r['arch']:22s} {r['shape']:12s} "
                         f"{'-- ' + r['status']:>20s}")
            continue
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} "
            f"{r['compute_s'] * 1e3:>8.2f} {r['memory_s'] * 1e3:>8.2f} "
            f"{r['collective_s'] * 1e3:>8.2f} {r['dominant'][:5]:>5s} "
            f"{r['useful_flops_ratio']:>7.2f} "
            f"{100 * r['roofline_fraction']:>6.1f}%")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun.json")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rows = report(args.dryrun, args.mesh)
    print(fmt_table(rows))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
