"""Profile-guided autotuning suite: calibrate, tune, and prove the win.

Three stages per run, the paper's config-dependence claim made
measurable (the AVX/NEON "When Should They Be Used?" observation that
intrinsic payoff depends on the machine configuration in ways static
models miss):

1. **Calibrate** — fit per-op correction factors from the simulator's
   retired counts (``repro.port.autotune.calibrate``) and install them
   as the registry's measured-count term.
2. **Tune** — per (kernel, target), search LMUL (register-pressure
   model) x retile factor cap x tail policy; every winning decision is
   simulator-fact-checked and conformance-gated, then persisted in the
   on-disk autotuning cache so a deploy restart starts tuned.
3. **Bench** — wall clock of the tuned compile against the static
   default at serving geometry (``benchmarks/port_suite.py``'s
   min-of-repeats machinery), with outputs asserted against the exact
   NumPy references under every tuned configuration.

Acceptance (--check): tuned beats static wall clock for >= 5 corpus
kernels on at least one rvv target, tuned retired counts never exceed
static, and decisions survive a cache reload.

  PYTHONPATH=src python benchmarks/autotune_suite.py          # writes BENCH_autotune.json
  PYTHONPATH=src python benchmarks/autotune_suite.py --check  # + acceptance gate
  PYTHONPATH=src python benchmarks/autotune_suite.py --check --quick
                                # CI mode: deterministic facts only on a
                                # kernel subset (no wall clock), plus the
                                # committed JSON's wall-win floor
"""
from __future__ import annotations

import json
import math
import os
import sys
import tempfile
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CORPUS = os.path.join(ROOT, "examples", "neon_corpus")
sys.path.insert(0, CORPUS)

import harness  # noqa: E402  (the corpus differential harness)

from repro import port  # noqa: E402
from repro.port import autotune  # noqa: E402

# tuning targets: the narrow end (most LMUL headroom) and the wide end
# of the paper's family
TUNE_TARGETS = ("rvv-128", "rvv-1024")

# knob-search geometry: the simulator retires instructions one by one
# in Python, so tuning measures at a small n — the decisions (LMUL,
# factor cap, tail policy) are structural and carry to serving sizes
TUNE_N, TUNE_TAIL_N = 256, 259

# wall-clock geometry mirrors port_suite's serving-realistic size
WALL_N, WALL_TAIL_N = 2048, 2051

# a wall win must clear measurement noise
WIN_RATIO = 1.05
MIN_WALL_WINS = 5

QUICK_KERNELS = 8


def _cases(n, tail_n):
    return list(harness.cases(n=n, tail_n=tail_n))


def _load(case):
    return port.compile_file(os.path.join(CORPUS, case.file),
                             name=case.kernel)


def _items(n, tail_n, seed=0, limit=None):
    """[(case, kernel, args)] for the corpus at the given geometry."""
    import numpy as np
    out = []
    for i, case in enumerate(_cases(n, tail_n)):
        if limit is not None and i >= limit:
            break
        rng = np.random.default_rng(seed + i)
        out.append((case, _load(case), case.make_args(rng)))
    return out


def calibrate_corpus(items):
    cal = autotune.calibrate([(k, a) for _, k, a in items])
    assert cal.factors, "calibration fit no factors"
    return cal


def tune_sweep(items, cal, targets=TUNE_TARGETS, cache=None):
    """Tune every (kernel, target); returns {target: {kernel: row}}."""
    c = cache if cache is not None else autotune.cache()
    c.set_calibration(cal)
    out = {t: {} for t in targets}
    for case, k, args in items:
        for t in targets:
            d = c.tune_or_get(k, args, t, calibration=cal)
            assert d.measured is None or d.static is None or \
                d.measured <= d.static, \
                f"{case.kernel}@{t}: tuned retires more than static " \
                f"({d.measured} > {d.static})"
            out[t][case.kernel] = {
                "lmul": d.lmul, "factor_cap": d.factor_cap,
                "tail": d.tail, "static_retired": d.static,
                "tuned_retired": d.measured,
                "retired_improvement": (
                    round(d.improvement, 3) if d.improvement else 1.0),
            }
    return out


def bench_wall_tuned(cal, targets=TUNE_TARGETS, seed=0, repeats=10):
    """Wall clock: static-default revec compile vs tuned compile.

    Same min-of-repeats discipline as port_suite.bench_wall; every
    tuned output is asserted against the exact NumPy reference — a
    tuned configuration that diverges fails the suite, not just the
    row.  The calibration is installed for the tuned compiles (the
    measured-count term steers selection) and uninstalled after.
    """
    import numpy as np
    rows = {t: {} for t in targets}
    for i, case in enumerate(_cases(WALL_N, WALL_TAIL_N)):
        k = _load(case)
        rng = np.random.default_rng(seed + i)
        args = case.make_args(rng)
        want = case.reference(*args)

        def timed(fn):
            outs = fn(*args)                      # compile + warmup
            _block(outs)
            best = math.inf
            for _ in range(repeats):
                t0 = time.perf_counter()
                outs = fn(*args)
                _block(outs)
                best = min(best, time.perf_counter() - t0)
            return outs, best

        for t in targets:
            static = k.compile(target=t, revec=True)
            out_s, t_static = timed(static)
            _assert_close(out_s, want, case, f"{t}/static")

            autotune.install(cal)
            try:
                tuned = k.compile(target=t, revec=True, tuned=True)
                out_t, t_tuned = timed(tuned)
            finally:
                autotune.uninstall()
            _assert_close(out_t, want, case, f"{t}/tuned")

            speedup = t_static / max(t_tuned, 1e-9)
            rows[t][case.kernel] = {
                "static_ms": round(t_static * 1e3, 4),
                "tuned_ms": round(t_tuned * 1e3, 4),
                "wall_speedup": round(speedup, 3),
                "win": speedup >= WIN_RATIO,
                "tuned_target": tuned.target.name,
                "tail": tuned.tail,
                "retile_factor": (tuned.retiling.factor
                                  if tuned.retiling else 1),
            }
    return rows


def _block(outs):
    import numpy as np
    if isinstance(outs, tuple):
        for o in outs:
            np.asarray(o)
    else:
        np.asarray(outs)


def _assert_close(got, want, case, what):
    import numpy as np
    got = got if isinstance(got, tuple) else (got,)
    want = want if isinstance(want, tuple) else (want,)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=max(case.rtol, 1e-5),
                                   atol=max(case.atol, 1e-6),
                                   err_msg=f"{case.kernel} [{what}]: "
                                           f"diverged from reference")


def check_persistence(items, cal, cache_path):
    """Tuned decisions must survive a fresh cache object reading the
    persisted file (the process-restart contract)."""
    fresh = autotune.AutotuneCache(cache_path, strict=True)
    n = 0
    for _, k, _args in items:
        for t in TUNE_TARGETS:
            d = fresh.get(k, t)
            assert d is not None, \
                f"{k.name}@{t}: tuned decision did not survive reload"
            n += 1
    rcal = fresh.calibration
    assert rcal is not None and rcal.factors == cal.factors, \
        "calibration did not survive reload"
    return n


def check(data):
    """Acceptance: the tuned configuration is a measured, persisted,
    conformant win."""
    wins = data["wall_wins"]
    best_t = max(wins, key=wins.get) if wins else None
    assert best_t and wins[best_t] >= MIN_WALL_WINS, \
        f"tuned wall-clock wins {wins} never reach the " \
        f">= {MIN_WALL_WINS} floor on any target"
    for t, rows in data["tuning"].items():
        for name, row in rows.items():
            tr, sr = row["tuned_retired"], row["static_retired"]
            assert tr is None or sr is None or tr <= sr, \
                f"{name}@{t}: cached decision retires more than static"
    print(f"# acceptance: {wins[best_t]} wall wins on {best_t} "
          f"(floor {MIN_WALL_WINS}); retired counts monotone OK")


def check_committed(path="BENCH_autotune.json"):
    """--quick CI gate on the committed artifact's wall rows (wall
    clock itself is too noisy to re-measure in CI)."""
    if not os.path.exists(path):
        raise AssertionError(f"--quick needs a committed {path}")
    with open(path) as f:
        data = json.load(f)
    check(data)


def emit_json(cal, tuning, wall, path="BENCH_autotune.json"):
    wall_wins = {t: sum(1 for r in rows.values() if r["win"])
                 for t, rows in wall.items()}
    data = {
        "suite": "autotune_corpus",
        "metric": "wall_clock_and_retired_instructions",
        "tune_n": TUNE_N, "wall_n": WALL_N,
        "targets": list(TUNE_TARGETS),
        "win_ratio": WIN_RATIO,
        "calibration": {
            "factors": {k: round(v, 4)
                        for k, v in sorted(cal.factors.items())},
            "fitted_on": list(cal.fitted_on),
        },
        "tuning": {t: dict(sorted(rows.items()))
                   for t, rows in tuning.items()},
        "wall": {t: dict(sorted(rows.items()))
                 for t, rows in wall.items()},
        "wall_wins": wall_wins,
    }
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return data


def quick(json_path="BENCH_autotune.json", regression=False):
    """CI mode: deterministic facts on a kernel subset, no wall clock.

    Calibrates and tunes the first QUICK_KERNELS corpus kernels at
    small n against a throwaway cache file, asserts the sim-retired
    improvements and the persistence round-trip, then gates the
    committed JSON's wall-win floor.
    """
    items = _items(TUNE_N, TUNE_TAIL_N, limit=QUICK_KERNELS)
    cal = calibrate_corpus(items)
    print(f"# calibration: {len(cal.factors)} op factors from "
          f"{len(items)} kernels on {', '.join(cal.fitted_on)}")
    with tempfile.TemporaryDirectory() as td:
        cache = autotune.AutotuneCache(os.path.join(td, "autotune.json"))
        tuning = tune_sweep(items, cal, cache=cache)
        improved = sum(
            1 for row in tuning[TUNE_TARGETS[0]].values()
            if row["retired_improvement"] > 1.0)
        assert improved >= min(5, len(items) - 2), \
            f"only {improved}/{len(items)} kernels improved retired " \
            f"counts on {TUNE_TARGETS[0]}"
        n = check_persistence(items, cal, cache.path)
        print(f"# {improved}/{len(items)} kernels improve retired "
              f"counts on {TUNE_TARGETS[0]}; {n} decisions survive "
              f"reload")
    if regression:
        check_committed(json_path)


def main(json_path="BENCH_autotune.json", regression=False):
    print(f"# autotune sweep: calibrate + knob search "
          f"(tune n={TUNE_N}) + wall clock (n={WALL_N})")
    items = _items(TUNE_N, TUNE_TAIL_N)
    cal = calibrate_corpus(items)
    print(f"# calibration: {len(cal.factors)} op factors fit on "
          f"{', '.join(cal.fitted_on)}")
    with tempfile.TemporaryDirectory() as td:
        cache = autotune.AutotuneCache(os.path.join(td, "autotune.json"))
        tuning = tune_sweep(items, cal, cache=cache)
        check_persistence(items, cal, cache.path)
        # the wall benchmark consults the same decisions through the
        # process-wide cache hook
        autotune.set_cache_path(cache.path)
        try:
            wall = bench_wall_tuned(cal)
        finally:
            autotune.reset_cache()
        data = emit_json(cal, tuning, wall, path=json_path)
    for t in TUNE_TARGETS:
        wins = data["wall_wins"][t]
        sp = [r["wall_speedup"] for r in data["wall"][t].values()]
        print(f"#  {t}: {wins}/{len(sp)} wall wins, speedup "
              f"{min(sp):.2f}x..{max(sp):.2f}x")
    if regression:
        check(data)
    print(f"# wrote {json_path}")


if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--quick" in argv:
        quick(regression="--check" in argv)
    else:
        main(regression="--check" in argv)
