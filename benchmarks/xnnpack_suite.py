"""Paper Figure-2 reproduction: the ten XNNPACK functions, customized
lowering vs original-SIMDe baseline, swept across the RVV width family.

Metric = dynamic vector-instruction count (the paper's Spike methodology;
see core/trace.py).  Both columns now come straight from the cost-driven
selector (core/registry.py):

  baseline   — the ladder choice under the ``use_policy('vector')`` cap
               (original SIMDe: customized conversions excluded, highest
               valid tier wins); the vector tier's cost model analyzes
               its own jaxpr with the generic-union 2x memory round-trip
               and, on targets without a vector libm, scalarized
               transcendentals (paper §3.2/§4.2),
  customized — unconstrained selection; on the RVV family the selector
               picks the customized (pallas-tier) lowering for all ten
               functions by evaluated cost, while *keeping the vector
               tier for simple arithmetic* (paper Listing 8) — asserted
               below via a vadd probe.

``explain()`` exposes the per-candidate analysis table behind each row.
``main()`` sweeps rvv-128/256/512/1024 (+ the beyond-paper TPU column)
and writes BENCH_xnnpack.json so the perf trajectory is machine-readable.

Workload sizes follow XNNPACK microkernel benchmark conventions
(MobileNet-ish layer shapes).
"""
from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp

from repro.core import targets, trace, use_target
from repro.core.registry import REGISTRY, TIERS
from repro.kernels import ops  # noqa: F401  (registers kernel lowerings)

KEY = jax.random.PRNGKey(0)

# The ten ops of the paper's Figure 2, in its plot order.
FIGURE2_OPS = ("gemm", "convhwc", "dwconv", "maxpool", "argmaxpool",
               "vrelu", "vsqrt", "vtanh", "vsigmoid", "ibilinear")


def _r(shape, seed=0, scale=1.0, dtype=jnp.float32):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape) * scale
            ).astype(dtype)


def workloads():
    """(name, op, args, kwargs) — one per paper benchmark function."""
    img = _r((56, 56, 64), 1)
    p = 56 * 56
    iy = jax.random.randint(jax.random.PRNGKey(2), (p,), 0, 54)
    ix = jax.random.randint(jax.random.PRNGKey(3), (p,), 0, 54)
    wy = jax.random.uniform(jax.random.PRNGKey(4), (p,))
    wx = jax.random.uniform(jax.random.PRNGKey(5), (p,))
    big = _r((1, 56, 56, 256), 6)
    return [
        ("gemm", "gemm", (_r((256, 512), 7), _r((512, 256), 8),
                          _r((256,), 9), -1.0, 1.0), {}),
        ("convhwc", "conv_hwc", (_r((1, 28, 28, 128), 10),
                                 _r((3, 3, 128, 128), 11, 0.1),
                                 _r((128,), 12)), {}),
        ("dwconv", "dwconv", (_r((1, 56, 56, 128), 13),
                              _r((3, 3, 128), 14, 0.3),
                              _r((128,), 15)), {}),
        ("maxpool", "maxpool", (big, (2, 2)), {}),
        ("argmaxpool", "argmaxpool", (big, (2, 2)), {}),
        ("vrelu", "vrelu", (_r((1024, 1024), 16), 0.0, 6.0), {}),
        ("vsqrt", "vsqrt", (jnp.abs(_r((1024, 1024), 17)) + 0.01,), {}),
        ("vtanh", "vtanh", (_r((1024, 1024), 18, 2.0),), {}),
        ("vsigmoid", "vsigmoid", (_r((1024, 1024), 19, 2.0),), {}),
        ("ibilinear", "ibilinear", (img, iy, ix, wy, wx), {}),
    ]


def run_target(target, check=False):
    """One Figure-2 column: per-op baseline vs selector-chosen lowering
    under ``target``, straight from the selection engine's cost models.

    ``check``: assert the paper's selection properties (only meaningful
    on the RVV family, where the baseline toolchain model applies).
    """
    target = targets.get_target(target)
    rows = []
    with use_target(target):
        # Listing 8: the selector must KEEP the vector tier for simple
        # arithmetic — a customized kernel cannot beat one vector op.
        probe = jnp.zeros((1024,), jnp.float32)
        arith = REGISTRY.explain("vadd", probe, probe, policy="pallas")
        if check:
            assert arith["chosen"] == "vector", arith
        for name, opname, args, kw in workloads():
            base = REGISTRY.explain(opname, *args, policy="vector", **kw)
            cust = REGISTRY.explain(opname, *args, policy="pallas", **kw)
            # Original SIMDe is a preprocessor *ladder*, not a cost
            # search: its baseline is the highest valid tier under the
            # cap (the vector port), even where the scalar loop would
            # model cheaper.
            ladder = max((c for c in base["candidates"]
                          if c["valid"] and c["cost"] is not None),
                         key=lambda c: TIERS.index(c["tier"]))
            ratio = ladder["cost"] / max(1, cust["chosen_cost"])
            rows.append({
                "name": name, "target": target.name,
                "baseline_tier": ladder["tier"],
                "customized_tier": cust["chosen"],
                "baseline_instrs": int(ladder["cost"]),
                "customized_instrs": int(cust["chosen_cost"]),
                "speedup": round(ratio, 2),
                "candidates": cust["candidates"],
            })
        if check:
            _check_figure2(rows)
    return rows


def _check_figure2(rows):
    """The paper's Figure-2 selection properties on an RVV target."""
    by_name = {r["name"]: r for r in rows}
    for name in FIGURE2_OPS:
        r = by_name[name]
        assert r["customized_tier"] == "pallas", \
            f"{name}: selector kept {r['customized_tier']}, not customized"
        assert r["speedup"] > 1.0, \
            f"{name}: customized not cheaper ({r['speedup']}x)"
    top2 = sorted(rows, key=lambda r: -r["speedup"])[:2]
    assert {t["name"] for t in top2} == {"vtanh", "vsigmoid"}, \
        f"largest wins should be vtanh/vsigmoid, got {[t['name'] for t in top2]}"


def run_rvv_sweep(check=True):
    """Sweep the paper's VLA width family — Figure 2 at every vlen."""
    return {w: run_target(w, check=check) for w in targets.RVV_FAMILY}


# ---------------------------------------------------------------------------
# Beyond-paper TPU column: instruction selection (MXU) + fusion (HBM)
# ---------------------------------------------------------------------------

def _kernel_io_bytes(opname, args, kw, out):
    arrays = [a for a in args if hasattr(a, "shape")]
    outs = jax.tree.leaves(out)
    return trace.io_bytes(*arrays, *outs)


def run_tpu(target="tpu-v5e"):
    """The adapted target: the baseline has a vector libm and XLA fuses
    away the SIMDe union round-trip, so the baseline column is the
    *un-overheaded* jaxpr count of the vector tier and the win is
    instruction selection (MXU macro-ops) + fusion (HBM traffic)."""
    rows = []
    with use_target(target):
        for name, opname, args, kw in workloads():
            cust = REGISTRY.explain(opname, *args, policy="pallas", **kw)
            low_v = REGISTRY.select(opname, *args, policy="vector", **kw)
            base_instrs = trace.jaxpr_vector_instrs(
                low_v.fn, *args, scalarize=False, union_overhead=False, **kw)
            is_arr = [hasattr(a, "shape") for a in args]
            arr_args = [a for a, ok in zip(args, is_arr) if ok]

            def _fn(*traced, _f=low_v.fn, _is=tuple(is_arr),
                    _args=args, _kw=kw):
                it = iter(traced)
                full = [next(it) if ok else a
                        for a, ok in zip(_args, _is)]
                return _f(*full, **_kw)

            out = jax.eval_shape(_fn, *arr_args)
            base_bytes = trace.jaxpr_hbm_bytes(low_v.fn, *args, **kw)
            cust_bytes = _kernel_io_bytes(opname, args, kw, out)
            rows.append({
                "name": name, "target": targets.get_target(target).name,
                "baseline_tier": low_v.tier,
                "customized_tier": cust["chosen"],
                "baseline_instrs": int(base_instrs),
                "customized_instrs": int(cust["chosen_cost"]),
                "speedup": round(base_instrs
                                 / max(1, cust["chosen_cost"]), 2),
                "baseline_bytes": int(base_bytes),
                "customized_bytes": int(cust_bytes),
                "traffic_ratio": round(base_bytes / max(1, cust_bytes), 2),
            })
    return rows


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------

def emit_json(sweep, tpu_rows, path="BENCH_xnnpack.json"):
    """Machine-readable perf trajectory: per-op baseline/customized
    dynamic instruction counts + ratio, per target width."""
    data = {"suite": "xnnpack_figure2",
            "metric": "dynamic_vector_instructions",
            "targets": {}}
    tpu_name = tpu_rows[0]["target"] if tpu_rows else "tpu"
    for tname, rows in list(sweep.items()) + [(tpu_name, tpu_rows)]:
        data["targets"][tname] = {
            r["name"]: {k: r[k] for k in
                        ("baseline_tier", "customized_tier",
                         "baseline_instrs", "customized_instrs", "speedup")
                        } | ({"traffic_ratio": r["traffic_ratio"]}
                             if "traffic_ratio" in r else {})
            for r in rows}
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    return path


def check_regression(data, baseline_path="BENCH_xnnpack.json"):
    """Exact-count gate vs the committed baseline.

    The metric is a deterministic cost-model evaluation, not a
    measurement — so the tolerance is ZERO: tiers must match and
    instruction counts must be *identical*.  (The ibilinear baseline
    drifted from PR 2's committed counts without tripping anything
    because this gate didn't exist; any intentional cost-model change
    now shows up as a reviewed baseline diff, never a silent shift.)
    """
    if not os.path.exists(baseline_path):
        print(f"# no committed {baseline_path}; skipping regression gate")
        return
    with open(baseline_path) as f:
        base = json.load(f)
    problems = []
    for tname, ops in base.get("targets", {}).items():
        fresh_ops = data["targets"].get(tname)
        if fresh_ops is None:
            problems.append(f"{tname}: target column disappeared")
            continue
        for name, row in ops.items():
            fr = fresh_ops.get(name)
            if fr is None:
                problems.append(f"{name}@{tname}: op disappeared")
                continue
            for key in ("baseline_instrs", "customized_instrs",
                        "baseline_tier", "customized_tier"):
                if fr[key] != row[key]:
                    problems.append(
                        f"{name}@{tname}: {key} {row[key]!r} -> "
                        f"{fr[key]!r}")
    if problems:
        raise AssertionError(
            "BENCH_xnnpack drift vs committed baseline (cost models are "
            "deterministic — every diff is a reviewed change):\n  "
            + "\n  ".join(problems))
    print(f"# regression gate vs {baseline_path}: exact match OK")


def main(json_path="BENCH_xnnpack.json", regression=False):
    sweep = run_rvv_sweep(check=True)
    print("# RVV cost model sweep (paper Figure 2 reproduction)")
    print(f"{'function':12s}", *(f"{w:>10s}" for w in targets.RVV_FAMILY))
    for i, name in enumerate(FIGURE2_OPS):
        cells = [f"{sweep[w][i]['speedup']:>9.2f}x" for w in targets.RVV_FAMILY]
        print(f"{name:12s}", *cells)
    sp = [r["speedup"] for r in sweep["rvv-128"]]
    print(f"# rvv-128 range: {min(sp):.2f}x .. {max(sp):.2f}x "
          f"(paper: 1.51x .. 5.13x)\n")

    tpu_rows = run_tpu()
    print("# TPU v5e cost model (beyond-paper adaptation)")
    print(f"{'function':12s} {'chosen':>8s} {'instr-speedup':>14s} "
          f"{'HBM-traffic-x':>14s}")
    for r in tpu_rows:
        print(f"{r['name']:12s} {r['customized_tier']:>8s} "
              f"{r['speedup']:>13.2f}x {r['traffic_ratio']:>13.2f}x")

    if regression:
        # gate BEFORE overwriting the committed baseline
        tpu_name = tpu_rows[0]["target"] if tpu_rows else "tpu"
        fresh = {"targets": {
            tname: {r["name"]: r for r in rows}
            for tname, rows in list(sweep.items()) + [(tpu_name,
                                                       tpu_rows)]}}
        check_regression(fresh, baseline_path=json_path)
    path = emit_json(sweep, tpu_rows, json_path)
    print(f"\n# wrote {path}")
    # legacy contract for benchmarks/run.py: 'rvv128' mirrors rvv-128
    out = {w: sweep[w] for w in sweep}
    out["rvv128"] = sweep["rvv-128"]
    out["tpu"] = tpu_rows
    return out


if __name__ == "__main__":
    main(regression="--check" in sys.argv[1:])
