"""Paper Figure-2 reproduction: the ten XNNPACK functions, customized
lowering vs original-SIMDe baseline.

Metric = dynamic vector-instruction count (the paper's Spike methodology;
see core/trace.py).  The baseline side runs the vector-tier lowering and
counts instructions from its traced jaxpr with transcendentals
*scalarized* (no vector libm on the baseline path — why the paper's
vtanh/vsigmoid show the largest wins); the customized side uses each
kernel's declared instruction model (grid x per-block ops, read off the
kernel body).  Wall-clock of the two jnp-visible paths is reported as a
secondary column (CPU, so indicative only).

Workload sizes follow XNNPACK microkernel benchmark conventions
(MobileNet-ish layer shapes).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import trace, use_policy
from repro.core.registry import REGISTRY
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _r(shape, seed=0, scale=1.0, dtype=jnp.float32):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape) * scale
            ).astype(dtype)


def workloads():
    """(name, op, args, kwargs) — one per paper benchmark function."""
    img = _r((56, 56, 64), 1)
    p = 56 * 56
    iy = jax.random.randint(jax.random.PRNGKey(2), (p,), 0, 54)
    ix = jax.random.randint(jax.random.PRNGKey(3), (p,), 0, 54)
    wy = jax.random.uniform(jax.random.PRNGKey(4), (p,))
    wx = jax.random.uniform(jax.random.PRNGKey(5), (p,))
    big = _r((1, 56, 56, 256), 6)
    return [
        ("gemm", "gemm", (_r((256, 512), 7), _r((512, 256), 8),
                          _r((256,), 9), -1.0, 1.0), {}),
        ("convhwc", "conv_hwc", (_r((1, 28, 28, 128), 10),
                                 _r((3, 3, 128, 128), 11, 0.1),
                                 _r((128,), 12)), {}),
        ("dwconv", "dwconv", (_r((1, 56, 56, 128), 13),
                              _r((3, 3, 128), 14, 0.3),
                              _r((128,), 15)), {}),
        ("maxpool", "maxpool", (big, (2, 2)), {}),
        ("argmaxpool", "argmaxpool", (big, (2, 2)), {}),
        ("vrelu", "vrelu", (_r((1024, 1024), 16), 0.0, 6.0), {}),
        ("vsqrt", "vsqrt", (jnp.abs(_r((1024, 1024), 17)) + 0.01,), {}),
        ("vtanh", "vtanh", (_r((1024, 1024), 18, 2.0),), {}),
        ("vsigmoid", "vsigmoid", (_r((1024, 1024), 19, 2.0),), {}),
        ("ibilinear", "ibilinear", (img, iy, ix, wy, wx), {}),
    ]


# ops whose baseline lowering scalarizes (libm calls defeat the baseline's
# auto-vectorizer) — mirrors the original-SIMDe RVV flow of the paper §4.2.
_SCALARIZED_BASELINE = {"vsqrt", "vtanh", "vsigmoid"}


def baseline_instrs(opname, args, kw) -> int:
    """Original SIMDe: vector-attribute jaxpr, scalarized transcendentals,
    2x union-memory round-trip per op (paper §3.2)."""
    low = REGISTRY.select(opname, *args, policy="vector", **kw)
    scalarize = opname in _SCALARIZED_BASELINE
    return trace.jaxpr_vector_instrs(low.fn, *args, scalarize=scalarize,
                                     union_overhead=True, **kw)


def customized_instrs(opname, args, kw) -> int:
    low = REGISTRY.select(opname, *args, policy="pallas", **kw)
    assert low.tier == "pallas", f"{opname} lacks a customized lowering"
    return int(low.cost(*args, **kw))


def wall_us(fn, *args, n=3, **kw):
    static = tuple(i for i, a in enumerate(args)
                   if not (hasattr(a, "shape") and hasattr(a, "dtype")))
    jfn = jax.jit(fn, static_argnums=static)
    out = jfn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(jfn(*args, **kw))
    return (time.perf_counter() - t0) / n * 1e6


def _kernel_io_bytes(opname, args, kw, out):
    arrays = [a for a in args if hasattr(a, "shape")]
    outs = jax.tree.leaves(out)
    return trace.io_bytes(*arrays, *outs)


def run(model="rvv128", report_wall=False):
    """model: 'rvv128' = the paper's vector width + scalar-libm baseline
    (faithful Figure-2 reproduction); 'tpu' = the adapted target where the
    baseline has a vector libm and the win is instruction selection (MXU)
    + fusion (HBM traffic) — the beyond-paper column."""
    target = trace.RVV128 if model == "rvv128" else trace.TARGET
    rows = []
    with trace.cost_target(target):
        for name, opname, args, kw in workloads():
            low_v = REGISTRY.select(opname, *args, policy="vector", **kw)
            if model == "rvv128":
                base = trace.jaxpr_vector_instrs(
                    low_v.fn, *args, union_overhead=True,
                    scalarize=opname in _SCALARIZED_BASELINE, **kw)
            else:
                base = trace.jaxpr_vector_instrs(low_v.fn, *args,
                                                 scalarize=False,
                                                 union_overhead=False, **kw)
            cust = customized_instrs(opname, args, kw)
            row = {"name": name, "model": model,
                   "baseline_instrs": int(base),
                   "customized_instrs": int(cust),
                   "speedup": round(base / max(1, cust), 2)}
            if model == "tpu":
                is_arr = [hasattr(a, "shape") for a in args]
                arr_args = [a for a, ok in zip(args, is_arr) if ok]

                def _fn(*traced, _f=low_v.fn, _is=tuple(is_arr),
                        _args=args, _kw=kw):
                    it = iter(traced)
                    full = [next(it) if ok else a
                            for a, ok in zip(_args, _is)]
                    return _f(*full, **_kw)

                out = jax.eval_shape(_fn, *arr_args)
                base_bytes = trace.jaxpr_hbm_bytes(low_v.fn, *args, **kw)
                cust_bytes = _kernel_io_bytes(opname, args, kw, out)
                row["baseline_bytes"] = int(base_bytes)
                row["customized_bytes"] = int(cust_bytes)
                row["traffic_ratio"] = round(base_bytes / max(1, cust_bytes),
                                             2)
            if report_wall:
                fn = getattr(ops, opname)
                with use_policy("vector"):
                    row["base_us"] = round(wall_us(fn, *args, **kw), 1)
            rows.append(row)
    return rows


def main():
    out = {}
    rows = run("rvv128")
    out["rvv128"] = rows
    print("# RVV-128 cost model (paper Figure 2 reproduction)")
    print(f"{'function':12s} {'baseline':>12s} {'customized':>12s} "
          f"{'speedup':>8s}")
    for r in rows:
        print(f"{r['name']:12s} {r['baseline_instrs']:>12d} "
              f"{r['customized_instrs']:>12d} {r['speedup']:>7.2f}x")
    sp = [r["speedup"] for r in rows]
    print(f"# range: {min(sp):.2f}x .. {max(sp):.2f}x "
          f"(paper: 1.51x .. 5.13x)\n")

    rows = run("tpu")
    out["tpu"] = rows
    print("# TPU v5e cost model (beyond-paper adaptation)")
    print(f"{'function':12s} {'instr-speedup':>14s} {'HBM-traffic-x':>14s}")
    for r in rows:
        print(f"{r['name']:12s} {r['speedup']:>13.2f}x "
              f"{r['traffic_ratio']:>13.2f}x")
    return out


if __name__ == "__main__":
    main()
